"""Multi-device tests (8 fake host devices, spawned in subprocesses because
the XLA device-count flag must be set before jax initializes)."""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.multidevice

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# prepended to every subprocess script: the shared AxisType-compat mesh
# constructor (import-safe before device init)
PREAMBLE = """
import jax
from repro.launch.mesh import make_mesh
"""


def run_with_devices(script: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c",
                          PREAMBLE + textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_index_build_search_insert():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed import ShardedJasperIndex
        from repro.core.construction import ConstructionParams

        mesh = make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        N, D, Q = 4096, 32, 64
        data = rng.normal(size=(N, D)).astype(np.float32)
        queries = rng.normal(size=(Q, D)).astype(np.float32)
        params = ConstructionParams(degree_bound=16, alpha=1.2, beam_width=16,
                                    max_iters=24, rev_cap=16, prune_chunk=256)
        idx = ShardedJasperIndex(mesh, D, capacity_per_shard=2048,
                                 construction=params)
        idx.build(data)
        assert idx.size == N
        ids, dists = idx.search(queries, k=10, beam_width=32)
        # ground truth on the dealt layout (global id = shard*stride+local)
        per = N // 4
        full = np.zeros((4 * 2048, D), np.float32)
        valid = np.zeros((4 * 2048,), bool)
        for s in range(4):
            full[s * 2048:s * 2048 + per] = data[s * per:(s + 1) * per]
            valid[s * 2048:s * 2048 + per] = True
        d = ((queries[:, None] - full[None]) ** 2).sum(-1)
        d[:, ~valid] = np.inf
        pos = np.argsort(d, axis=1)[:, :10]
        gt = (pos // 2048) * idx.id_stride + pos % 2048
        ids = np.asarray(ids)
        rec = np.mean([len(set(ids[i]) & set(gt[i])) / 10 for i in range(Q)])
        assert rec > 0.85, rec
        # streaming insert
        idx.insert(rng.normal(size=(4, 64, D)).astype(np.float32))
        assert idx.size == N + 256
        ids2, _ = idx.search(queries, k=10, beam_width=32)
        assert ids2.shape == (Q, 10)
        print("RECALL", rec)
    """)
    assert "RECALL" in out


def test_sharded_lifecycle_unified_core():
    """Full mutation lifecycle on the shard_map-wrapped IndexCore: deletes
    on one shard are never returned from any shard's merge (all search
    paths incl. the fused kernel scorer), consolidation frees slots,
    insert derives PER-SHARD offsets (uneven shards reuse their own freed
    slots while others advance their own tails), and save/load round-trips
    tombstones + free pools through the single-device .npz format."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed import ShardedJasperIndex
        from repro.core.index import JasperIndex
        from repro.core.construction import ConstructionParams

        mesh = make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        N, D, Q, CAP = 2048, 32, 64, 1024
        data = rng.normal(size=(N, D)).astype(np.float32)
        queries = rng.normal(size=(Q, D)).astype(np.float32)
        params = ConstructionParams(degree_bound=16, alpha=1.2, beam_width=16,
                                    max_iters=24, rev_cap=16, prune_chunk=256)
        idx = ShardedJasperIndex(mesh, D, capacity_per_shard=CAP,
                                 construction=params,
                                 quantization="rabitq", bits=4)
        STRIDE = idx.id_stride          # global id = shard*STRIDE + local
        idx.build(data)
        assert idx.size == N

        # delete on shard 0 ONLY -> no search path may return those ids
        dead = np.arange(100, 140)          # shard-0 locals == global ids
        assert idx.delete(dead) == 40
        for label, fn in [
            ("exact", lambda: idx.search(queries, 10, beam_width=32)),
            ("exact_kernel", lambda: idx.search(
                queries, 10, beam_width=32, use_kernels=True)),
            ("rabitq", lambda: idx.search_rabitq(queries, 10, beam_width=32)),
            ("rabitq_kernel", lambda: idx.search_rabitq(
                queries, 10, beam_width=32, use_kernels=True)),
            ("rabitq_exclude", lambda: idx.search_rabitq(
                queries, 10, beam_width=32, use_kernels=True,
                traverse_deleted=False)),
        ]:
            ids, _ = fn()
            leaked = np.intersect1d(np.asarray(ids), dead)
            assert leaked.size == 0, (label, leaked)

        # consolidate frees the slots (shard-local repair, no coordination)
        stats = idx.consolidate()
        assert stats["n_freed"] == 40
        assert idx.size == N - 40

        # uneven insert: shard 0 must reuse ITS freed slots, shards 1-3
        # must advance THEIR own tails (the uniform-start bug would write
        # shard 1-3 rows over unwritten offsets derived from shard 0)
        gids = idx.insert(rng.normal(size=(4, 8, D)).astype(np.float32))
        assert np.unique(gids).size == gids.size
        per = N // 4
        s0_local = np.sort(gids[gids // STRIDE == 0] % STRIDE)
        assert (np.isin(s0_local, dead)).all(), s0_local   # reused slots
        for s in (1, 2, 3):
            loc = np.sort(gids[gids // STRIDE == s] % STRIDE)
            assert (loc == per + np.arange(8)).all(), (s, loc)
        assert idx.size == N - 40 + 32
        # every search path still clean: reused slots are live again,
        # remaining tombstones (none) can't leak
        ids2, _ = idx.search_rabitq(queries, 10, beam_width=32,
                                    use_kernels=True)
        still_dead = np.setdiff1d(dead, gids[gids // STRIDE == 0] % STRIDE)
        assert np.intersect1d(np.asarray(ids2), still_dead).size == 0

        # save/load round-trip (tombstones + free pools included)
        import tempfile, os
        d = tempfile.mkdtemp()
        path = os.path.join(d, "ck")
        idx.save(path)
        idx2 = ShardedJasperIndex.load(mesh, path)
        assert idx2.size == idx.size
        a, da = idx.search(queries, 10, beam_width=32)
        b, db = idx2.search(queries, 10, beam_width=32)
        assert (np.asarray(a) == np.asarray(b)).all()
        assert np.allclose(np.asarray(da), np.asarray(db))
        # every shard file is a valid single-device checkpoint
        solo = JasperIndex.load(path + ".shard0")
        assert solo.capacity == CAP
        from repro.core.index_core import core_size
        assert solo.size == core_size(idx2.shard_core(0))
        # free pools round-tripped: next insert reuses identically
        g1 = idx.insert(rng.normal(size=(4, 4, D)).astype(np.float32))
        g2 = idx2.insert(rng.normal(size=(4, 4, D)).astype(np.float32))
        assert (g1 == g2).all()
        print("LIFECYCLE_OK")
    """)
    assert "LIFECYCLE_OK" in out


def test_sharded_grow_and_single_device_parity():
    """Per-shard grow is bit-identical on packed codes, and sharded search
    matches single-device JasperIndex recall within noise on the same
    data (both run the same core_search; only the merge differs)."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed import ShardedJasperIndex
        from repro.core.index import JasperIndex
        from repro.core.construction import ConstructionParams

        mesh = make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(1)
        N, D, Q, CAP = 2048, 32, 128, 1024
        data = rng.normal(size=(N, D)).astype(np.float32)
        queries = rng.normal(size=(Q, D)).astype(np.float32)
        params = ConstructionParams(degree_bound=16, alpha=1.2, beam_width=16,
                                    max_iters=24, rev_cap=16, prune_chunk=256)

        sh = ShardedJasperIndex(mesh, D, capacity_per_shard=CAP,
                                construction=params,
                                quantization="rabitq", bits=4)
        sh.build(data)
        solo = JasperIndex(D, capacity=N, construction=params,
                           quantization="rabitq", bits=4)
        solo.build(data)

        # parity: at the same per-search beam, shard-and-merge must never
        # LOSE recall vs one device (4 independent beams over quarters
        # cover at least as much as one beam over the whole set) ...
        r_sh = sh.recall(queries, k=10, beam_width=48, quantized=True)
        r_solo = solo.recall(queries, k=10, beam_width=48, quantized=True)
        assert r_sh > 0.93, r_sh
        assert r_sh >= r_solo - 0.02, (r_sh, r_solo)
        # ... and at a MATCHED total candidate budget (4 shards x 48 vs
        # one beam of 192) the two backends agree within noise
        r_solo_eq = solo.recall(queries, k=10, beam_width=192,
                                quantized=True)
        assert abs(r_sh - r_solo_eq) < 0.05, (r_sh, r_solo_eq)

        # grow: copy-extension only — packed codes per shard bit-identical
        # and GLOBAL ids stable (id encoding is stride-, not cap-, based)
        ids_pre, _ = sh.search(queries[:16], k=10, beam_width=32,
                               quantized=True)
        packed0 = np.asarray(sh.core.codes.packed).reshape(4, CAP, -1)
        adj0 = np.asarray(sh.core.adjacency).reshape(4, CAP, -1)
        sh.grow(2 * CAP)
        packed1 = np.asarray(sh.core.codes.packed).reshape(4, 2 * CAP, -1)
        adj1 = np.asarray(sh.core.adjacency).reshape(4, 2 * CAP, -1)
        assert (packed1[:, :CAP] == packed0).all()
        assert (packed1[:, CAP:] == 0).all()
        assert (adj1[:, :CAP] == adj0).all()
        assert (adj1[:, CAP:] == -1).all()
        ids_post, _ = sh.search(queries[:16], k=10, beam_width=32,
                                quantized=True)
        assert (np.asarray(ids_pre) == np.asarray(ids_post)).all(), \
            "global ids changed across grow"
        r_grown = sh.recall(queries, k=10, beam_width=48, quantized=True)
        assert abs(r_grown - r_sh) < 1e-6, (r_grown, r_sh)
        print("GROW_PARITY_OK", r_sh, r_solo)
    """)
    assert "GROW_PARITY_OK" in out


def test_sharded_reshard_restore():
    """Elastic resharding: a checkpoint saved at 4 shards restores at 2
    and 8; live rows survive (packed codes bit-identical through the
    translation), dead ids translate to -1, recall at equal total budget
    stays within tolerance, and the fused kernel path leaks no
    tombstones after the move."""
    out = run_with_devices("""
        import tempfile, os, numpy as np, jax
        from repro.core.distributed import ShardedJasperIndex
        from repro.core.construction import ConstructionParams

        mesh4 = make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(5)
        N, D, Q = 2048, 32, 64
        data = rng.normal(size=(N, D)).astype(np.float32)
        queries = rng.normal(size=(Q, D)).astype(np.float32)
        params = ConstructionParams(degree_bound=16, alpha=1.2, beam_width=16,
                                    max_iters=24, rev_cap=16, prune_chunk=256)
        idx = ShardedJasperIndex(mesh4, D, capacity_per_shard=1024,
                                 construction=params,
                                 quantization="rabitq", bits=4)
        idx.build(data)
        dead = np.arange(100, 160)            # shard-0 locals == global ids
        idx.delete(dead)
        d = tempfile.mkdtemp(); path = os.path.join(d, "ck")
        idx.save(path)
        r_base = idx.recall(queries, 10, beam_width=64, quantized=True)
        packed4 = np.asarray(idx.core.codes.packed).reshape(4, 1024, -1)

        for shards, mesh in [(2, make_mesh((2, 4), ("data", "model"))),
                             (8, make_mesh((8,), ("data",)))]:
            idx2 = ShardedJasperIndex.load(mesh, path, n_shards=shards)
            assert idx2.n_shards == shards
            assert idx2.size == N - 60
            tr = idx2.reshard_translation
            assert tr is not None and len(tr) == N - 60
            # dead ids are not in the translation (unreturnable forever)
            assert (tr.apply(dead) == -1).all()
            # bijection: no two live ids collide after the move
            mapped = tr.apply(tr.old_ids)
            assert (mapped >= 0).all()
            assert np.unique(mapped).size == mapped.size
            # packed codes of moved rows are bit-identical (no re-encode)
            new_packed = np.asarray(idx2.core.codes.packed).reshape(
                shards, idx2.cap, -1)
            probe = tr.old_ids[:: max(1, len(tr) // 64)]
            for og, ng in zip(probe, tr.apply(probe)):
                s_o, l_o = og // idx.id_stride, og % idx.id_stride
                s_n, l_n = ng // idx2.id_stride, ng % idx2.id_stride
                assert (packed4[s_o, l_o] == new_packed[s_n, l_n]).all()
            # equal total search budget: S' shards x (256/S') beam
            r = idx2.recall(queries, 10, beam_width=256 // shards,
                            quantized=True)
            assert r >= r_base - 0.05, (shards, r, r_base)
            # fused kernel path: zero tombstone leaks after the reshard
            ids_k, _ = idx2.search_rabitq(queries, 10,
                                          beam_width=256 // shards,
                                          use_kernels=True)
            ret = np.asarray(ids_k).ravel(); ret = ret[ret >= 0]
            assert not idx2.tombstoned(ret).any()
            # restored index keeps serving: insert + delete still work
            gids = idx2.insert(rng.normal(size=(shards, 8, D))
                               .astype(np.float32))
            assert np.unique(gids).size == gids.size
            idx2.delete(gids.reshape(-1)[:4])

        # n_shards guard: asking for a count the mesh cannot provide raises
        try:
            ShardedJasperIndex.load(make_mesh((2, 4), ("data", "model")),
                                    path, n_shards=3)
            raise SystemExit("guard did not fire")
        except ValueError:
            pass
        print("RESHARD_OK")
    """)
    assert "RESHARD_OK" in out


def test_sharded_rebalance_and_service_hook():
    """Skewed deletes drift shards uneven; rebalance() levels live counts
    by moving rows (packed codes re-derive bit-identically), returns an
    identity-default translation for outstanding tickets, and the
    AnnsService imbalance trigger drives it between ticks."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.core.distributed import ShardedJasperIndex
        from repro.core.construction import ConstructionParams
        from repro.serving.anns_service import AnnsService

        mesh = make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(6)
        N, D, Q = 2048, 32, 64
        data = rng.normal(size=(N, D)).astype(np.float32)
        queries = rng.normal(size=(Q, D)).astype(np.float32)
        params = ConstructionParams(degree_bound=16, alpha=1.2, beam_width=16,
                                    max_iters=24, rev_cap=16, prune_chunk=256)
        idx = ShardedJasperIndex(mesh, D, capacity_per_shard=1024,
                                 construction=params,
                                 quantization="rabitq", bits=4)
        idx.build(data)
        # delete 300 rows on shard 0 only -> heavy skew
        idx.delete(np.arange(100, 400))
        assert idx.shard_imbalance > 0.5
        st = idx.rebalance(tolerance=0.05)
        counts = idx.shard_live_counts()
        assert counts.max() - counts.min() <= 1, counts
        assert st["n_moved"] > 0
        tr = st["translation"]
        # moved rows got new ids; unmoved ids translate to themselves
        moved = tr.old_ids[tr.apply(tr.old_ids) != tr.old_ids]
        assert moved.size == st["n_moved"]
        assert int(tr.apply(np.asarray([50]))[0]) == 50
        # moved rows are findable under their NEW ids and dead under old
        assert not idx.tombstoned(tr.apply(tr.old_ids)).any()
        r = idx.recall(queries, 10, beam_width=64, quantized=True)
        assert r > 0.85, r
        ids_k, _ = idx.search_rabitq(queries, 10, beam_width=64,
                                     use_kernels=True)
        ret = np.asarray(ids_k).ravel(); ret = ret[ret >= 0]
        assert not idx.tombstoned(ret).any()

        # service hook: imbalance past the threshold rebalances the tick
        svc = AnnsService(idx, k=10, beam_width=48,
                          rebalance_threshold=0.3, verify=True)
        # skew shard 1 this time: delete 250 of its currently-live rows
        cand = idx.id_stride + np.arange(512)
        live1 = cand[~idx.tombstoned(cand)]
        res = svc.step(deletes=live1[:250], queries=queries)
        assert res.rebalanced is not None and res.rebalanced["n_moved"] > 0
        assert svc.stats.n_rebalances == 1
        c = idx.shard_live_counts()
        assert (c.max() - c.min()) <= 1, c
        # below threshold: the hook stays quiet
        res2 = svc.step(queries=queries)
        assert res2.rebalanced is None
        print("REBALANCE_OK")
    """)
    assert "REBALANCE_OK" in out


def test_sharded_mips_matches_single_device():
    """Sharded MIPS (global max-norm fold before per-shard augmentation):
    brute force argmax-IP parity with exact inner products AND with the
    single-device MIPS driver, surviving a streaming norm raise."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.core.distributed import ShardedJasperIndex
        from repro.core.index import JasperIndex
        from repro.core.construction import ConstructionParams

        mesh = make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(7)
        D = 24
        params = ConstructionParams(degree_bound=16, alpha=1.2, beam_width=16,
                                    max_iters=24, rev_cap=16, prune_chunk=256)
        sh = ShardedJasperIndex(mesh, D, capacity_per_shard=512,
                                construction=params, metric="mips")
        d1 = rng.normal(size=(1024, D)).astype(np.float32)
        sh.build(d1)
        # second batch RAISES the global max-norm: every shard must
        # re-augment its written rows or the reduction silently corrupts
        d2 = (6.0 * rng.normal(size=(4, 128, D))).astype(np.float32)
        sh.insert(d2)
        q = rng.normal(size=(40, D)).astype(np.float32)

        allrows = np.concatenate([d1.reshape(4, 256, D), d2],
                                 axis=1).reshape(-1, D)
        per = 256 + 128
        ip = q @ allrows.T
        gt_pos = ip.argmax(1)
        gt_gid = (gt_pos // per) * sh.id_stride + gt_pos % per
        got, _ = sh.brute_force(q, 1)
        assert (np.asarray(got)[:, 0] == gt_gid).all()     # exact reduction

        # parity with the single-device MIPS driver at matched budget
        solo = JasperIndex(D, capacity=1536, metric="mips",
                           construction=params)
        solo.build(d1)
        solo.insert(d2.reshape(-1, D))
        gt10_sh, _ = sh.brute_force(q, 10)
        ids_sh, _ = sh.search(q, 10, beam_width=48)
        ids_solo, _ = solo.search(q, 10, beam_width=192)
        gt10_solo, _ = solo.brute_force(q, 10)
        def rec(ids, gt):
            ids, gt = np.asarray(ids), np.asarray(gt)
            return np.mean([len(set(ids[i]) & set(gt[i])) / 10
                            for i in range(ids.shape[0])])
        r_sh, r_solo = rec(ids_sh, gt10_sh), rec(ids_solo, gt10_solo)
        assert r_sh >= r_solo - 0.1, (r_sh, r_solo)
        # quantization rejects nothing: rabitq + mips compose
        shq = ShardedJasperIndex(mesh, D, capacity_per_shard=512,
                                 construction=params, metric="mips",
                                 quantization="rabitq", bits=4)
        shq.build(d1)
        ids_q, _ = shq.search_rabitq(q, 10, beam_width=48)
        assert np.asarray(ids_q).shape == (40, 10)
        print("MIPS_OK", r_sh, r_solo)
    """)
    assert "MIPS_OK" in out


def test_sharded_train_step_runs_and_matches_single_device():
    out = run_with_devices("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.data.synthetic import make_lm_batch
        from repro.launch import shardings as shd
        from repro.launch.mesh import make_debug_mesh
        from repro.models.model import init_params
        from repro.models.sharding_ctx import sharding_rules
        from repro.training.optimizer import OptimizerConfig
        from repro.training.train_loop import init_train_state, make_train_step

        cfg = dataclasses.replace(ARCHS["stablelm-1.6b"].reduced(),
                                  dtype="float32")
        opt = OptimizerConfig(peak_lr=1e-3, total_steps=10, warmup_steps=0)
        step_fn = make_train_step(cfg, opt)
        params = init_params(cfg, jax.random.PRNGKey(0))
        state = init_train_state(cfg, params)
        batch = make_lm_batch(cfg, 4, 32, seed=0, step=0)

        # single device reference
        s_ref, m_ref = jax.jit(step_fn)(state, batch)

        mesh = make_debug_mesh(2, 2)
        s_abs = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        s_shd = shd.sanitize_shardings(
            shd.train_state_shardings(mesh, cfg), s_abs, mesh)
        b_shd = {k: shd.sanitize_shardings(v, batch[k], mesh)
                 for k, v in shd.batch_shardings(mesh, cfg).items()}
        with mesh, sharding_rules(mesh):
            jstep = jax.jit(step_fn, in_shardings=(s_shd, b_shd),
                            out_shardings=(s_shd, None))
            state_d = jax.device_put(state, s_shd)
            batch_d = jax.device_put(batch, b_shd)
            s_out, m_out = jstep(state_d, batch_d)
        err = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            s_ref.params, jax.device_get(s_out).params)))
        assert err < 2e-4, err
        assert abs(float(m_ref["loss"]) - float(m_out["loss"])) < 1e-3
        print("SHARDED_MATCH", err)
    """)
    assert "SHARDED_MATCH" in out


def test_compressed_psum_close_to_exact():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.training.compression import compressed_psum

        mesh = make_mesh((8,), ("data",))
        g = jnp.asarray(np.random.default_rng(0).normal(size=(8, 512)),
                        jnp.float32)

        def f(g, key):
            exact = jax.lax.psum(g, "data")
            approx = compressed_psum(g, "data", key[0])
            return exact, approx

        keys = jax.random.split(jax.random.PRNGKey(0), 8)
        from repro.compat import shard_map
        fn = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P(), P()), check_vma=False)
        exact, approx = fn(g, keys)
        rel = float(jnp.max(jnp.abs(exact - approx))
                    / (jnp.max(jnp.abs(exact)) + 1e-9))
        assert rel < 0.15, rel
        print("PSUM_REL", rel)
    """)
    assert "PSUM_REL" in out


def test_checkpoint_reshards_across_mesh_shapes():
    """Elastic restore: save on a (4,2) mesh, restore onto (2,4)."""
    out = run_with_devices("""
        import tempfile, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training.checkpoint import save_checkpoint, restore_checkpoint

        mesh1 = make_mesh((4, 2), ("data", "model"))
        mesh2 = make_mesh((2, 4), ("data", "model"))
        x = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
        tree = {"w": jax.device_put(
            x, NamedSharding(mesh1, P("data", "model")))}
        d = tempfile.mkdtemp()
        save_checkpoint(d, 3, tree)
        target = {"w": NamedSharding(mesh2, P("model", None))}
        back = restore_checkpoint(d, 3, tree, target)
        assert back["w"].sharding == target["w"]
        assert (np.asarray(back["w"]) == np.asarray(x)).all()
        print("RESHARD_OK")
    """)
    assert "RESHARD_OK" in out


def test_collectives_counted_with_loop_multiplier():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline.hlo_analyzer import analyze_hlo

        mesh = make_mesh((8,), ("data",))

        def body(x, w):
            y = x @ w
            y = jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, P(None, None)))
            return y, None

        def f(x, ws):
            x, _ = jax.lax.scan(body, x, ws)
            return x

        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
        sx = NamedSharding(mesh, P(None, "data"))
        sw = NamedSharding(mesh, P(None, "data", None))
        c = jax.jit(f, in_shardings=(sx, sw)).lower(x, ws).compile()
        ana = analyze_hlo(c.as_text())
        total = ana["collectives"]["total"]
        # the in-loop collective must be weighted by ~6 iterations
        assert total["count"] >= 6, total
        print("COLL_COUNT", total["count"])
    """)
    assert "COLL_COUNT" in out


def test_compressed_dp_step_tracks_exact():
    """int8-compressed gradient sync trains ~ as well as exact psum."""
    out = run_with_devices("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.data.synthetic import make_lm_batch
        from repro.models.model import init_params
        from repro.training.optimizer import OptimizerConfig
        from repro.training.train_loop import init_train_state
        from repro.training.dp_step import make_dp_train_step_compressed

        cfg = dataclasses.replace(ARCHS["stablelm-1.6b"].reduced(),
                                  dtype="float32")
        opt = OptimizerConfig(peak_lr=1e-3, total_steps=20, warmup_steps=0)
        mesh = make_mesh((8,), ("data",))
        step_c = make_dp_train_step_compressed(cfg, opt, mesh, compress=True)
        step_e = make_dp_train_step_compressed(cfg, opt, mesh, compress=False)
        # separate buffers: step donation would otherwise alias them
        sc = init_train_state(cfg, init_params(cfg, jax.random.PRNGKey(0)))
        se = init_train_state(cfg, init_params(cfg, jax.random.PRNGKey(0)))
        keys = jax.random.split(jax.random.PRNGKey(1), 8)
        lc = le = None
        for t in range(12):
            batch = make_lm_batch(cfg, 8, 32, seed=0, step=0)
            sc, mc = step_c(sc, batch, keys)
            se, me = step_e(se, batch, keys)
            lc, le = float(mc["loss"]), float(me["loss"])
        # both memorize the fixed batch; compressed within 10% of exact
        assert le < 6.0 and lc < 6.0, (lc, le)
        assert abs(lc - le) / le < 0.1, (lc, le)
        print("DP_COMPRESS", lc, le)
    """)
    assert "DP_COMPRESS" in out
