"""Property-test harness shim: hypothesis when installed, else a seeded
deterministic fallback.

The real hypothesis is strictly better (shrinking, example database,
coverage-guided generation) — but it is an optional dependency, and the
property suite guards system invariants that must run in EVERY
environment the tier-1 suite runs in. When hypothesis is absent this
shim substitutes a minimal strategy/`@given` implementation that draws a
reduced, deterministic sample (seeded by the test name, capped at
`FALLBACK_MAX_EXAMPLES` per test so the suite stays inside its wall
clock). Supported strategy surface: `st.integers`, `st.floats`,
`st.sampled_from`, keyword-style `@given`, and `@settings(max_examples,
deadline)` — exactly what tests/test_properties.py uses.
"""

from __future__ import annotations

HAVE_HYPOTHESIS = True
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    HAVE_HYPOTHESIS = False


    import zlib

    import numpy as np

    FALLBACK_MAX_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    def settings(max_examples: int = 25, deadline=None, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def wrapper():
                n = min(getattr(wrapper, "_max_examples", 25),
                        FALLBACK_MAX_EXAMPLES)
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**drawn)
            # NOT functools.wraps: pytest must see a zero-arg signature,
            # or it would resolve the strategy kwargs as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
