"""Standing-query scheduler: flush policy under a fake clock (no
wall-clock sleeps anywhere in this module), padding hygiene (coalesced
padded dispatch is bit-identical to per-query dispatch and padding rows
never leak into tickets), priority lanes, backpressure shedding, the
LRU-bounded plan cache, and the zero-steady-state-retrace contract under
mixed-spec open-loop traffic."""

import numpy as np
import pytest

from repro.core.construction import ConstructionParams
from repro.core.index import JasperIndex
from repro.core.search_spec import (
    BUCKET_LADDER,
    PlanCache,
    SearchResult,
    SearchSpec,
    bucket_for,
    pad_to_bucket,
)
from repro.serving.anns_service import AnnsService
from repro.serving.loadgen import bursty_trace, poisson_trace
from repro.serving.scheduler import (
    SchedulerConfig,
    StandingQueryScheduler,
    summarize_handles,
)

SMALL = ConstructionParams(degree_bound=16, alpha=1.2, beam_width=16,
                           max_iters=24, rev_cap=16, prune_chunk=256)
DIMS = 24


# ---------------------------------------------------------------------------
# Deterministic harness: fake clock + fake dispatch (manual readiness)
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


class FakeBatch:
    """ready()/take() protocol with manual readiness."""

    def __init__(self, n: int, k: int = 3):
        self.ready_flag = False
        self._n, self._k = n, k

    def ready(self) -> bool:
        return self.ready_flag

    def take(self) -> SearchResult:
        n, k = self._n, self._k
        ids = np.arange(n * k, dtype=np.int32).reshape(n, k)
        return SearchResult(ids=ids, dists=ids.astype(np.float32),
                            n_hops=np.zeros(n, np.int32), generation=0)


class FakeLaneDispatch:
    """Records every dispatched batch shape; batches complete only when
    the test flips them ready."""

    def __init__(self):
        self.batches: list[FakeBatch] = []
        self.shapes: list[tuple] = []

    def __call__(self, queries) -> FakeBatch:
        self.shapes.append(tuple(queries.shape))
        b = FakeBatch(queries.shape[0])
        self.batches.append(b)
        return b

    def finish_all(self) -> None:
        for b in self.batches:
            b.ready_flag = True


def make_sched(clock, *, lanes=("default",), priorities=None, **cfg):
    cfg.setdefault("buckets", (1, 8, 32))
    cfg.setdefault("slo_budget_s", 1.0)
    sched = StandingQueryScheduler(clock=clock, **cfg)
    dispatches = {}
    for i, name in enumerate(lanes):
        d = FakeLaneDispatch()
        prio = priorities[i] if priorities else 0
        sched.add_lane(name, dispatch=d, priority=prio)
        dispatches[name] = d
    return sched, dispatches


Q = np.zeros(DIMS, np.float32)


# ---------------------------------------------------------------------------
# Bucket / padding helpers
# ---------------------------------------------------------------------------

def test_bucket_for_ladder():
    assert [bucket_for(n) for n in (1, 2, 8, 9, 32, 33, 128, 500)] == \
        [1, 8, 8, 32, 32, 128, 128, 128]
    assert bucket_for(3, (4, 16)) == 4
    with pytest.raises(ValueError):
        bucket_for(0)


def test_pad_to_bucket_repeats_last_row_and_reports_valid_count():
    q = np.arange(3 * DIMS, dtype=np.float32).reshape(3, DIMS)
    padded, n = pad_to_bucket(q, (1, 8))
    assert n == 3 and padded.shape == (8, DIMS)
    assert np.array_equal(padded[:3], q)
    assert np.array_equal(padded[3:], np.repeat(q[-1:], 5, axis=0))
    exact, n2 = pad_to_bucket(q[:1], (1, 8))
    assert n2 == 1 and exact.shape == (1, DIMS)   # exact rung: no copy


# ---------------------------------------------------------------------------
# Flush policy (fake clock — zero wall-clock dependence)
# ---------------------------------------------------------------------------

def test_idle_flush_serves_partial_batch_immediately():
    """Device idle -> a partial batch dispatches at once (latency when
    idle); batching only happens while the device is busy."""
    clk = FakeClock()
    sched, d = make_sched(clk)
    sched.submit(Q)
    sched.submit(Q)
    sched.poll()
    assert d["default"].shapes == [(8, DIMS)]     # 2 padded up to rung 8
    assert sched.stats.flush_idle == 1
    assert sched.stats.padded_rows == 6
    assert sched.stats.dispatched == 2


def test_bucket_full_flush_while_busy():
    """With work in flight, a queue reaching the top bucket flushes for
    reason 'full' (throughput when loaded)."""
    clk = FakeClock()
    sched, d = make_sched(clk, max_inflight=2)
    sched.submit(Q)
    sched.poll()                                  # idle flush, now busy
    for _ in range(32):
        sched.submit(Q)
    sched.poll()
    assert d["default"].shapes == [(1, DIMS), (32, DIMS)]
    assert sched.stats.flush_full == 1
    assert sched.stats.mean_batch_occupancy == 1.0


def test_deadline_flush_at_budget_half_spent():
    """While the device is busy a partial batch waits — until the oldest
    query's SLO budget is flush_fraction spent, then it goes."""
    clk = FakeClock()
    sched, d = make_sched(clk, max_inflight=2, slo_budget_s=1.0,
                          flush_fraction=0.5)
    sched.submit(Q)
    sched.poll()                                  # occupy the device
    assert d["default"].shapes == [(1, DIMS)]
    sched.submit(Q, slo_budget_s=1.0)
    clk.advance(0.49)
    sched.poll()
    assert len(d["default"].shapes) == 1          # 49% spent: still waiting
    clk.advance(0.02)
    sched.poll()                                  # 51% spent: flush
    assert d["default"].shapes[-1] == (1, DIMS)
    assert sched.stats.flush_deadline == 1


def test_per_query_slo_override_drives_deadline():
    clk = FakeClock()
    sched, d = make_sched(clk, max_inflight=2, slo_budget_s=10.0)
    sched.submit(Q)
    sched.poll()                                  # occupy the device
    sched.submit(Q, slo_budget_s=0.010)           # tight per-query budget
    clk.advance(0.006)
    sched.poll()
    assert sched.stats.flush_deadline == 1        # 60% of 10ms spent


def test_deadline_is_min_over_queue_not_head():
    """Regression: a tight-budget query queued BEHIND a lax one must pull
    the flush forward. The old policy only looked at the queue head's
    budget, so the tight query's deadline was invisible until the lax
    head's (much later) deadline fired."""
    clk = FakeClock()
    sched, d = make_sched(clk, max_inflight=2, slo_budget_s=10.0)
    sched.submit(Q)
    sched.poll()                                  # occupy the device
    sched.submit(Q, slo_budget_s=10.0)            # lax head: deadline @ 5s
    clk.advance(0.001)
    sched.submit(Q, slo_budget_s=0.010)           # tight: deadline @ 6ms
    clk.advance(0.004)
    sched.poll()
    assert sched.stats.flush_deadline == 0        # tight at 40%: waiting
    clk.advance(0.003)                            # tight now 70% spent
    sched.poll()                                  # head-only policy would
    assert sched.stats.flush_deadline == 1        # have slept until ~5s
    # both queries left in the SAME flush (FIFO: head goes with it)
    assert d["default"].shapes[-1] == (8, DIMS)
    assert sched.stats.dispatched == 3


def test_priority_lane_dispatch_order():
    """Both lanes overdue, one dispatch slot: the lower priority value
    wins even though the other lane's query is older."""
    clk = FakeClock()
    sched, d = make_sched(clk, lanes=("lo", "hi"), priorities=(1, 0),
                          max_inflight=2, slo_budget_s=1.0)
    sched.submit(Q, lane="lo")
    sched.poll()                                  # idle flush goes to lo
    assert sched.flush_log[-1][0] == "lo"
    sched.submit(Q, lane="lo")
    clk.advance(0.01)
    sched.submit(Q, lane="hi")                    # younger than lo's
    clk.advance(0.6)                              # both overdue now
    sched.poll()                                  # ONE free slot
    assert sched.flush_log[-1][0] == "hi"         # priority beats age
    assert sched.inflight_depth == 2
    d["hi"].finish_all()
    d["lo"].finish_all()
    sched.poll()
    sched.poll()                                  # freed slots: lo drains
    assert [e[0] for e in sched.flush_log] == ["lo", "hi", "lo"]


def test_backpressure_sheds_to_rejected_ticket():
    clk = FakeClock()
    sched, d = make_sched(clk, max_queue=4, max_inflight=1)
    sched.submit(Q)
    sched.poll()                                  # in flight, never ready
    admitted = [sched.submit(Q) for _ in range(4)]
    shed = sched.submit(Q)
    assert all(h.status == "queued" for h in admitted)
    assert shed.status == "rejected" and shed.result is None
    assert sched.stats.rejected == 1
    assert sched.queue_depth == 4                 # bounded: no growth
    rep = summarize_handles([*admitted, shed], wall_s=1.0)
    assert rep["rejected"] == 1 and rep["completed"] == 0


def test_overlap_bounded_inflight_and_inorder_harvest():
    clk = FakeClock()
    sched, d = make_sched(clk, max_inflight=2, slo_budget_s=0.1)
    hs = [sched.submit(Q)]
    sched.poll()                                  # idle flush: batch 1
    hs.append(sched.submit(Q))
    clk.advance(1.0)
    sched.poll()                                  # deadline flush: batch 2
    assert sched.inflight_depth == 2              # double buffer is full
    hs.append(sched.submit(Q))
    clk.advance(1.0)
    sched.poll()
    assert sched.inflight_depth == 2              # bounded: no 3rd dispatch
    d["default"].batches[0].ready_flag = True
    done = sched.poll()                           # harvest head, dispatch 3
    assert [h.status for h in hs] == ["done", "inflight", "inflight"]
    assert done and done[0] is hs[0]
    assert len(d["default"].shapes) == 3
    d["default"].finish_all()
    done = sched.poll()
    assert all(h.status == "done" for h in hs)
    assert sched.stats.completed == 3
    # fake-clock latency accounting: all three spent fake time queueing
    assert all(h.latency_s is not None and h.latency_s >= 0 for h in hs)


def test_drain_flushes_everything_and_blocks():
    clk = FakeClock()
    sched, d = make_sched(clk, max_inflight=1)

    # auto-completing dispatch (ready immediately) so drain can finish
    class AutoBatch(FakeBatch):
        def ready(self):
            return True

    auto = []
    sched.add_lane("auto", dispatch=lambda q: (
        auto.append(tuple(q.shape)), AutoBatch(q.shape[0]))[1])
    hs = [sched.submit(Q, lane="auto") for _ in range(70)]
    done = sched.drain()
    assert all(h.status == "done" for h in hs)
    assert len(done) == 70
    assert sched.queue_depth == 0 and sched.inflight_depth == 0
    # 70 queries through ladder (1,8,32): two full 32s then a padded 8
    assert sched.stats.flush_drain >= 1
    assert sum(n for _, _, n, _ in sched.flush_log) == 70


def test_slo_miss_accounting():
    clk = FakeClock()
    sched, d = make_sched(clk, max_inflight=1, slo_budget_s=0.05)
    h = sched.submit(Q)
    sched.poll()
    clk.advance(1.0)                              # way past budget
    d["default"].finish_all()
    sched.poll()
    assert h.status == "done" and h.slo_met is False
    assert sched.stats.slo_misses == 1


def test_scheduler_config_validation():
    with pytest.raises(ValueError, match="flush_fraction"):
        SchedulerConfig(flush_fraction=0.0)
    with pytest.raises(ValueError, match="buckets"):
        SchedulerConfig(buckets=())
    with pytest.raises(ValueError, match=">= 1"):
        SchedulerConfig(max_inflight=0)
    assert SchedulerConfig(buckets=(32, 1, 8)).buckets == (1, 8, 32)
    with pytest.raises(KeyError):
        sched = StandingQueryScheduler(clock=FakeClock())
        sched.submit(Q, lane="nope")
    with pytest.raises(ValueError, match="need an index"):
        StandingQueryScheduler(clock=FakeClock()).add_lane("x")


# ---------------------------------------------------------------------------
# Real-index integration: padding hygiene + plan-cache behavior
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(11)
    idx = JasperIndex(DIMS, capacity=640, construction=SMALL,
                      quantization="rabitq", bits=4)
    idx.build(rng.normal(size=(500, DIMS)).astype(np.float32))
    queries = rng.normal(size=(5, DIMS)).astype(np.float32)
    return idx, queries


GRID = [
    ("exact/jnp", SearchSpec(k=5, beam_width=16)),
    ("exact/kernel", SearchSpec(k=5, beam_width=16, use_kernels=True)),
    ("rabitq/jnp", SearchSpec(k=5, beam_width=16, quantized=True)),
    ("rabitq/kernel", SearchSpec(k=5, beam_width=16, quantized=True,
                                 use_kernels=True)),
]


@pytest.mark.parametrize("label,spec", GRID, ids=[g[0] for g in GRID])
def test_coalesced_padded_equals_per_query_dispatch(built, label, spec):
    """THE padding-hygiene regression: a coalesced padded dispatch (5
    queries padded to the 8-bucket) is bit-identical, per query, to
    one-query-at-a-time dispatch through the same scheduler, on every
    backend cell — the batch a query lands in (and the padding rows
    that ride along) must never change its answer. Padding content
    differs between the two runs (repeat-last of 5 mixed rows vs a
    single row repeated 8x), so this also proves padding rows don't
    bleed into valid rows."""
    idx, queries = built
    sched = StandingQueryScheduler(idx, spec, buckets=(8,),
                                   slo_budget_s=10.0)
    handles = [sched.submit(q) for q in queries]
    sched.drain()
    assert sched.stats.batches == 1               # ONE coalesced dispatch
    assert sched.stats.padded_rows == 3
    solo_sched = StandingQueryScheduler(idx, spec, buckets=(8,),
                                        slo_budget_s=10.0)
    ses = idx.searcher(spec)
    for i, h in enumerate(handles):
        assert h.status == "done"
        solo_sched.submit(queries[i])
        (solo,) = solo_sched.drain()
        assert np.array_equal(h.ids, solo.ids), label
        assert np.array_equal(h.dists, solo.dists), label
        assert h.n_hops == solo.n_hops, label
        assert h.generation == solo.generation
        # the ticket is exactly k wide — no padding-row spill-over
        assert h.ids.shape == (5,) and h.dists.shape == (5,)
        # against the raw batch-1 executable: same neighbours always;
        # dists may drift by an ULP on the jnp path (XLA compiles a
        # different reduction for a different batch shape)
        raw = ses.search(queries[i:i + 1])
        assert np.array_equal(h.ids, np.asarray(raw.ids)[0]), label
        np.testing.assert_allclose(h.dists, np.asarray(raw.dists)[0],
                                   rtol=1e-6)


def test_mixed_spec_traffic_zero_steady_state_retraces(built):
    """Open-loop mixed-spec traffic (two lanes, every bucket shape):
    after one warmup pass the plan cache serves EVERYTHING — zero
    retraces, zero misses, across a fresh scheduler too (plans belong
    to the index, not the scheduler)."""
    idx, _ = built
    rng = np.random.default_rng(7)
    pool = rng.normal(size=(64, DIMS)).astype(np.float32)
    lanes = {"exact": (SearchSpec(k=5, beam_width=16), 1)}
    svc = AnnsService(idx, spec=SearchSpec(k=5, beam_width=16,
                                           quantized=True))
    trace = poisson_trace(5000.0, 150, n_queries=64, seed=3,
                          lanes=("default", "exact"),
                          lane_weights=(0.7, 0.3))
    # warmup: every (lane, rung) shape explicitly — which shapes a serve
    # pass coalesces depends on harvest timing (device readiness), so
    # traffic alone cannot deterministically cover the ladder
    for spec in (svc.spec, lanes["exact"][0]):
        ses = idx.searcher(spec)
        for b in (1, 8, 32):
            ses.search(pool[:b])
    svc.serve(trace, pool, lanes=lanes, buckets=(1, 8, 32),
              realtime=False)                     # warmup: scheduler path
    before = idx.plans.stats.snapshot()
    rep, handles = svc.serve(trace, pool, lanes=lanes, buckets=(1, 8, 32),
                             realtime=False)
    delta = idx.plans.stats.delta(before)
    assert delta["traces"] == 0, delta            # zero steady-state
    assert delta["misses"] == 0, delta
    assert rep["completed"] == 150 and rep["rejected"] == 0
    assert rep["flush_reasons"]["full"] + rep["flush_reasons"]["idle"] \
        + rep["flush_reasons"]["deadline"] + rep["flush_reasons"]["drain"] \
        == rep["batches"]


def test_serve_folds_service_stats_and_metrics(built):
    idx, _ = built
    rng = np.random.default_rng(8)
    pool = rng.normal(size=(16, DIMS)).astype(np.float32)
    svc = AnnsService(idx, spec=SearchSpec(k=5, beam_width=16,
                                           quantized=True))
    svc.metrics()                                 # histograms live
    trace = poisson_trace(3000.0, 40, n_queries=16, seed=5)
    rep, handles = svc.serve(trace, pool, buckets=(1, 8), realtime=False)
    assert svc.stats.n_search_queries == 40
    assert svc.stats.hops_sum > 0
    snap = svc.metrics_snapshot()
    assert snap["scheduler.completed"] == 40
    assert snap["scheduler.queue_depth"] == 0
    assert snap["scheduler.batch_occupancy"]["count"] == \
        snap["scheduler.batches"]
    assert snap["search.latency_us"]["count"] >= 40
    # the snapshot is the schema obs_report validates
    import importlib.util
    import json
    import pathlib
    json.dumps(snap)
    loc = importlib.util.spec_from_file_location(
        "obs_report",
        pathlib.Path(__file__).resolve().parents[1] / "scripts"
        / "obs_report.py")
    obs_report = importlib.util.module_from_spec(loc)
    loc.loader.exec_module(obs_report)
    obs_report.check_snapshot(snap)
    sched_series = obs_report.check_scheduler(snap)
    assert sched_series is not None
    assert sched_series["batches"] == sum(
        sched_series[f"flush_{r}"]
        for r in ("full", "deadline", "idle", "drain"))


def test_rejected_handles_carry_no_query_payload(built):
    idx, queries = built
    sched = StandingQueryScheduler(
        idx, SearchSpec(k=5, beam_width=16), buckets=(1,),
        max_queue=1, max_inflight=1, slo_budget_s=10.0)
    a = sched.submit(queries[0])
    b = sched.submit(queries[1])                  # queue full -> shed
    assert b.status == "rejected" and b.query is None
    done = sched.drain()
    assert a.status == "done" and len(done) == 1


# ---------------------------------------------------------------------------
# LRU-bounded plan cache
# ---------------------------------------------------------------------------

def test_plan_cache_lru_eviction_and_counter():
    cache = PlanCache(capacity=2)
    built = []

    def builder(tag):
        def build():
            built.append(tag)
            return tag
        return build

    assert cache.get("a", builder("a")) == "a"
    assert cache.get("b", builder("b")) == "b"
    assert cache.get("a", builder("a2")) == "a"   # hit refreshes a's recency
    assert cache.get("c", builder("c")) == "c"    # evicts b (LRU), not a
    assert cache.stats.evictions == 1
    assert cache.get("a", builder("a3")) == "a"   # a survived
    assert cache.get("b", builder("b2")) == "b2"  # b is gone: rebuilt
    assert cache.stats.evictions == 2
    assert len(cache) == 2
    assert built == ["a", "b", "c", "b2"]
    assert cache.stats.as_dict()["evictions"] == 2


def test_plan_cache_capacity_validation_and_shrink():
    with pytest.raises(ValueError):
        PlanCache(capacity=0)
    cache = PlanCache()                            # unbounded default
    for i in range(5):
        cache.get(i, lambda i=i: (lambda: i))
    assert len(cache) == 5 and cache.stats.evictions == 0
    cache.capacity = 2                             # shrinking evicts now
    assert len(cache) == 2 and cache.stats.evictions == 3


def test_index_plan_cache_capacity_kwarg_and_snapshot():
    rng = np.random.default_rng(3)
    idx = JasperIndex(DIMS, capacity=320, construction=SMALL,
                      plan_cache_capacity=2)
    idx.build(rng.normal(size=(200, DIMS)).astype(np.float32))
    q = rng.normal(size=(4, DIMS)).astype(np.float32)
    base = len(idx.plans)                          # build-time plans, if any
    for k in (3, 4, 5):                            # 3 distinct search plans
        idx.searcher(SearchSpec(k=k, beam_width=16)).search(q)
    assert len(idx.plans) <= 2
    assert idx.plans.stats.evictions >= 1 + max(0, base - 2)
    svc = AnnsService(idx, spec=SearchSpec(k=5, beam_width=16))
    snap = svc.metrics_snapshot()
    assert snap["plan_cache.capacity"] == 2
    assert snap["plan_cache.evictions"] == idx.plans.stats.evictions


def test_bursty_trace_mean_rate_and_determinism():
    t1 = bursty_trace(500.0, 400, n_queries=8, seed=9)
    t2 = bursty_trace(500.0, 400, n_queries=8, seed=9)
    assert t1 == t2                                # seeded: byte-identical
    # long-run mean offered rate stays within 2x of nominal (it's a
    # random modulated process; exactness is not the contract)
    dur = t1[-1].at
    assert 0.5 * 500 <= len(t1) / dur <= 2.0 * 500
    # arrival times strictly increase and queries hit the pool
    ats = [a.at for a in t1]
    assert all(b > a for a, b in zip(ats, ats[1:]))
    assert all(0 <= a.query_id < 8 for a in t1)
