"""End-to-end launcher smoke tests: the production CLIs actually run."""

import argparse
import os

import pytest

from repro.launch.train import run as train_run


def _args(**kw):
    base = dict(arch="xlstm-125m", reduced=True, steps=6, batch=2, seq=32,
                lr=1e-3, grad_accum=1, seed=0, mesh="none", multi_pod=False,
                ckpt_dir=None, ckpt_every=3, resume=False, log_every=3)
    base.update(kw)
    return argparse.Namespace(**base)


def test_train_launcher_runs():
    metrics = train_run(_args())
    assert metrics["steps"] == 6
    assert metrics["loss"] > 0


def test_train_launcher_checkpoint_resume(tmp_path):
    d = str(tmp_path / "ckpt")
    m1 = train_run(_args(steps=6, ckpt_dir=d))
    assert os.path.exists(os.path.join(d, "step_00000006.npz"))
    # resume continues from the saved step and finishes more steps
    m2 = train_run(_args(steps=9, ckpt_dir=d, resume=True))
    assert m2["steps"] == 9


def test_train_launcher_grad_accum():
    metrics = train_run(_args(steps=4, batch=4, grad_accum=2))
    assert metrics["steps"] == 4


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "zamba2-2.7b"])
def test_train_launcher_other_archs(arch):
    metrics = train_run(_args(arch=arch, steps=3))
    assert metrics["steps"] == 3


def test_dryrun_input_structs_cover_all_cells():
    """input_specs() produces shardable ShapeDtypeStructs for every cell."""
    from repro.configs import ARCHS, SHAPES, cell_is_runnable
    from repro.launch.dryrun import input_structs
    import jax
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            ok, _ = cell_is_runnable(cfg, shape)
            if not ok:
                continue
            structs = input_structs(cfg, shape)
            for v in structs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)
                assert all(d > 0 for d in v.shape)
