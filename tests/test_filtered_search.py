"""Filtered & multi-tenant search: label-plane plumbing, the fused
filter epilogue across every search path, and the tenant veneer.

The contract under test (docs/filtered_search.md):

  * a filter NEVER leaks: a filtered search returns only ids whose label
    row intersects the filter bitset — on every backend x scorer x
    fusion x filter_mode combination (exclude gates the walk in the
    kernel epilogue, traverse gates only the returned frontier; both
    return zero out-of-filter ids);
  * filter-absent specs are bit-identical to pre-filter behavior and
    resolve to the same plan-cache keys (filter VALUES are runtime
    operands — only presence is static);
  * label rows survive delete/consolidate/grow/checkpoint/reshard
    bit-identically;
  * tenants are label bits: isolation, quotas, ownership checks, and
    per-tenant stats ride the same machinery.
"""

import numpy as np
import pytest

from repro.core.construction import ConstructionParams
from repro.core.index import JasperIndex
from repro.core.index_core import bitmap_test_np
from repro.core.mutations import (
    N_LABEL_BYTES,
    N_LABELS,
    filter_to_bytes,
    pack_label_rows,
)
from repro.core.search_spec import SearchSpec

SEED = 99
N, D, Q, K, BEAM = 512, 16, 16, 8, 32
SMALL = ConstructionParams(degree_bound=16, alpha=1.2, beam_width=16,
                           max_iters=24, rev_cap=16, prune_chunk=256)


# ---------------------------------------------------------------------------
# Label-plane primitives
# ---------------------------------------------------------------------------

def test_filter_to_bytes_sets_exactly_the_requested_bits():
    fb = filter_to_bytes((0, 7, 8, 31))
    assert fb.shape == (N_LABEL_BYTES,) and fb.dtype == np.uint8
    got = [b for byte in range(N_LABEL_BYTES) for b in range(8)
           if int(fb[byte]) >> b & 1]
    # bit index = byte*8 + bit
    assert [byte * 8 + b for byte in range(N_LABEL_BYTES)
            for b in range(8) if int(fb[byte]) >> b & 1] == [0, 7, 8, 31]
    with pytest.raises(ValueError):
        filter_to_bytes((N_LABELS,))
    with pytest.raises(ValueError):
        filter_to_bytes((-1,))


def test_pack_label_rows_forms():
    # None -> all-zero rows (match nothing)
    assert not pack_label_rows(None, 3).any()
    # scalar -> one bit on every row
    rows = pack_label_rows(2, 3)
    assert rows.shape == (3, N_LABEL_BYTES)
    assert (rows[:, 0] == 4).all() and not rows[:, 1:].any()
    # per-row sequences
    rows = pack_label_rows([(0,), (0, 9), ()], 3)
    assert rows[0, 0] == 1 and rows[1, 0] == 1 and rows[1, 1] == 2
    assert not rows[2].any()
    with pytest.raises(ValueError):
        pack_label_rows([(0,)], 3)          # length mismatch


def test_bitmap_test_np_guards_negative_and_out_of_range_ids():
    """Regression: ids of -1 (padding) or past the bitmap's bit count
    used to wrap into a real byte index and alias another row's bit.
    Now they are domain-masked to False."""
    bits = np.zeros(4, np.uint8)
    bits[3] = 0x80                          # bit 31 set (the LAST bit)
    ids = np.array([-1, -8, 31, 32, 1000])
    got = bitmap_test_np(bits, ids)
    assert got.tolist() == [False, False, True, False, False]
    # the old wraparound: -1 % 32 == 31 would have aliased bit 31 -> True
    assert not bitmap_test_np(bits, np.array([-1]))[0]


# ---------------------------------------------------------------------------
# SearchSpec surface
# ---------------------------------------------------------------------------

def test_spec_filter_validation():
    assert SearchSpec(k=5).resolve().filtered is False
    r = SearchSpec(k=5, filter=(1, 2), filter_mode="exclude").resolve()
    assert r.filtered and r.filter_mode == "exclude"
    # scalar filter accepted
    assert SearchSpec(k=5, filter=3).resolve().filtered
    # filter_mode normalizes to "traverse" when no filter is present
    assert SearchSpec(k=5, filter_mode="exclude").resolve() \
        == SearchSpec(k=5).resolve()
    with pytest.raises(ValueError):
        SearchSpec(k=5, filter=()).resolve()
    with pytest.raises(ValueError):
        SearchSpec(k=5, filter=(N_LABELS,)).resolve()
    with pytest.raises(ValueError):
        SearchSpec(k=5, filter=(-1,)).resolve()
    with pytest.raises(ValueError):
        SearchSpec(k=5, filter=(0,), filter_mode="bogus").resolve()


def test_resolved_spec_is_value_free():
    """Filter VALUES never reach the resolved (static, plan-key) spec:
    two specs differing only in filter value resolve identically."""
    a = SearchSpec(k=5, filter=(1,), filter_mode="exclude").resolve()
    b = SearchSpec(k=5, filter=(2, 7), filter_mode="exclude").resolve()
    assert a == b
    fb = SearchSpec(k=5, filter=(1,)).filter_bytes()
    assert fb is not None and fb.shape == (N_LABEL_BYTES,)
    assert SearchSpec(k=5).filter_bytes() is None


def test_spec_filter_roundtrips_via_dict():
    s = SearchSpec(k=5, filter=(1, 4), filter_mode="exclude")
    assert SearchSpec.from_dict(s.to_dict()) == s


# ---------------------------------------------------------------------------
# The filtered matrix (single device)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def labeled_index():
    rng = np.random.default_rng(SEED)
    data = rng.normal(size=(N, D)).astype(np.float32)
    labels = (np.arange(N) % 4).astype(np.int32)     # 4 partitions
    idx = JasperIndex(D, capacity=N, construction=SMALL,
                      quantization="rabitq", bits=4, seed=SEED)
    idx.build(data, labels=labels)
    queries = rng.normal(size=(Q, D)).astype(np.float32)
    return idx, labels, queries


PATHS = [
    pytest.param(quantized, path,
                 id=f"{'rabitq' if quantized else 'exact'}-{path}")
    for quantized in (False, True)
    for path in ("jnp", "kernel", "hop", "megakernel")
]


def _path_spec(path, quantized, **kw):
    base = dict(k=K, beam_width=BEAM, quantized=quantized)
    if path == "kernel":
        base["use_kernels"] = True
    elif path in ("hop", "megakernel"):
        base["fusion"] = path
    return SearchSpec(**base, **kw)


@pytest.mark.parametrize("quantized,path", PATHS)
@pytest.mark.parametrize("mode", ["traverse", "exclude"])
def test_filtered_search_never_leaks(labeled_index, quantized, path, mode):
    idx, labels, queries = labeled_index
    spec = _path_spec(path, quantized, filter=(2,), filter_mode=mode)
    ids = np.asarray(idx.searcher(spec).search(queries).ids)
    returned = ids[ids >= 0]
    assert returned.size, "filtered search returned nothing"
    assert (labels[returned] == 2).all(), (
        quantized, path, mode, returned[labels[returned] != 2][:8])


@pytest.mark.parametrize("quantized,path", PATHS)
def test_filter_off_is_bit_identical(labeled_index, quantized, path):
    """A filter-absent spec on a labeled index returns exactly what an
    unlabeled index returns — the label plane is inert until a filter
    asks for it — and both resolve to the same plan-key spec."""
    idx, _, queries = labeled_index
    spec = _path_spec(path, quantized)
    res = idx.searcher(spec).search(queries)
    # a fresh identical index WITHOUT labels
    rng = np.random.default_rng(SEED)
    data = rng.normal(size=(N, D)).astype(np.float32)
    bare = JasperIndex(D, capacity=N, construction=SMALL,
                       quantization="rabitq", bits=4, seed=SEED)
    bare.build(data)
    ref = bare.searcher(spec).search(queries)
    assert np.array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    assert np.array_equal(np.asarray(res.dists), np.asarray(ref.dists))


def test_filter_values_share_one_plan(labeled_index):
    """Two different filter VALUES reuse one compiled plan; presence
    still splits (filtered vs not are different executables)."""
    idx, _, queries = labeled_index
    spec1 = _path_spec("hop", True, filter=(1,), filter_mode="exclude")
    spec2 = _path_spec("hop", True, filter=(3,), filter_mode="exclude")
    assert spec1.resolve() == spec2.resolve()
    idx.searcher(spec1).search(queries)
    before = len(idx.plans)
    idx.searcher(spec2).search(queries)
    assert len(idx.plans) == before
    r1 = np.asarray(idx.searcher(spec1).search(queries).ids)
    r2 = np.asarray(idx.searcher(spec2).search(queries).ids)
    _, labels, _ = labeled_index
    assert (labels[r1[r1 >= 0]] == 1).all()
    assert (labels[r2[r2 >= 0]] == 3).all()


def test_multi_label_filter_is_a_union(labeled_index):
    idx, labels, queries = labeled_index
    spec = _path_spec("jnp", True, filter=(0, 3), filter_mode="exclude")
    ids = np.asarray(idx.searcher(spec).search(queries).ids)
    returned = ids[ids >= 0]
    assert np.isin(labels[returned], (0, 3)).all()


def test_filtered_telemetry_counts_filter_misses(labeled_index):
    """Exclude-mode telemetry: out-of-filter candidates land in `masked`
    (after the tombstone test — a dead candidate counts once)."""
    idx, _, queries = labeled_index
    spec = _path_spec("megakernel", True, filter=(2,),
                      filter_mode="exclude").with_(telemetry="on")
    res = idx.searcher(spec).search(queries)
    assert res.telemetry is not None
    assert (np.asarray(res.telemetry.masked) > 0).any()


# ---------------------------------------------------------------------------
# Label persistence through the mutation lifecycle
# ---------------------------------------------------------------------------

def test_labels_survive_delete_consolidate_grow_checkpoint(tmp_path):
    rng = np.random.default_rng(5)
    data = rng.normal(size=(256, D)).astype(np.float32)
    labels = (np.arange(256) % 2).astype(np.int32)
    idx = JasperIndex(D, capacity=256, construction=SMALL, seed=5)
    idx.build(data, labels=labels)
    plane0 = np.asarray(idx.core.mut.labels).copy()
    assert plane0[:256].any()

    idx.delete(np.arange(0, 64))              # tombstone: labels retained
    assert np.array_equal(np.asarray(idx.core.mut.labels), plane0)
    idx.consolidate()                         # freed: labels still in rows
    live = ~idx.tombstoned(np.arange(256))
    plane1 = np.asarray(idx.core.mut.labels)
    assert np.array_equal(plane1[live], plane0[live])

    # freed slots recycle label-CLEAN, then get the new batch's labels
    new_ids = idx.insert(rng.normal(size=(32, D)).astype(np.float32),
                         labels=np.full(32, 1, np.int32))
    plane2 = np.asarray(idx.core.mut.labels)
    assert (plane2[new_ids, 0] == 2).all() and not plane2[new_ids, 1:].any()

    idx.grow(512)                             # copy-extension: bit-identical
    plane3 = np.asarray(idx.core.mut.labels)
    assert np.array_equal(plane3[:256], plane2[:256])
    assert not plane3[256:].any()

    path = str(tmp_path / "labeled.npz")
    idx.save(path)
    idx2 = JasperIndex.load(path)
    assert np.array_equal(np.asarray(idx2.core.mut.labels), plane3)


def test_legacy_checkpoint_loads_with_zero_labels(tmp_path):
    """Checkpoints written before the label plane load with all-zero
    labels (match nothing) instead of failing."""
    rng = np.random.default_rng(6)
    idx = JasperIndex(D, capacity=64, construction=SMALL, seed=6)
    idx.build(rng.normal(size=(64, D)).astype(np.float32))
    path = str(tmp_path / "legacy.npz")
    idx.save(path)
    # strip the labels array to simulate a pre-label checkpoint
    arrs = dict(np.load(path, allow_pickle=True))
    arrs.pop("labels")
    np.savez(path, **arrs)
    idx2 = JasperIndex.load(path)
    assert not np.asarray(idx2.core.mut.labels).any()


def test_labels_survive_reshard_bit_identically():
    from repro.core.index_core import (core_build, core_live_locals,
                                       core_set_labels, init_core)
    from repro.core.resharding import reshard_cores
    rng = np.random.default_rng(7)
    cores, planes = [], []
    for s in range(2):
        c = init_core(128, D, SMALL.degree_bound)
        c = core_build(c, rng.normal(size=(100, D)).astype(np.float32),
                       params=SMALL)
        rows = rng.integers(0, 256, size=(100, N_LABEL_BYTES)).astype(
            np.uint8)
        c = core_set_labels(c, np.arange(100, dtype=np.int32), rows)
        cores.append(c)
        planes.append(rows)
    res = reshard_cores(cores, old_id_stride=512, n_shards=3, params=SMALL)
    old = np.concatenate([s * 512 + np.asarray(core_live_locals(c))
                          for s, c in enumerate(cores)])
    new = res.translation.apply(old)
    rows = np.concatenate(planes)
    for og, ng, row in zip(old, new, rows):
        g, l = ng // res.id_stride, ng % res.id_stride
        assert np.array_equal(np.asarray(res.cores[g].mut.labels)[l], row)


# ---------------------------------------------------------------------------
# Tenant namespaces (serving veneer)
# ---------------------------------------------------------------------------

@pytest.fixture()
def tenant_service():
    from repro.serving.anns_service import AnnsService
    rng = np.random.default_rng(11)
    idx = JasperIndex(D, capacity=1024, construction=SMALL,
                      quantization="rabitq", seed=11)
    svc = AnnsService(idx, spec=SearchSpec(k=5, beam_width=24,
                                           quantized=True))
    svc.register_tenant("acme", quota_rows=100)
    svc.register_tenant("bolt")
    ids_a = svc.tenant_insert(
        "acme", rng.normal(size=(64, D)).astype(np.float32))
    ids_b = svc.tenant_insert(
        "bolt", rng.normal(size=(64, D)).astype(np.float32))
    q = rng.normal(size=(4, D)).astype(np.float32)
    return svc, ids_a, ids_b, q


def test_tenant_bits_and_exhaustion():
    from repro.serving.anns_service import AnnsService
    idx = JasperIndex(D, capacity=64, construction=SMALL)
    svc = AnnsService(idx, spec=SearchSpec(k=5))
    bits = [svc.register_tenant(f"t{i}") for i in range(N_LABELS)]
    assert bits == list(range(N_LABELS))
    with pytest.raises(ValueError):
        svc.register_tenant("one-too-many")
    with pytest.raises(ValueError):
        svc.register_tenant("t0")             # duplicate name


def test_tenant_isolation_both_modes(tenant_service):
    svc, ids_a, ids_b, q = tenant_service
    for mode in ("traverse", "exclude"):
        t = svc.tenant_search("acme", q, filter_mode=mode)
        got = set(t.ids.ravel().tolist()) - {-1}
        assert got and got <= set(ids_a.tolist()), (mode, got)
        t = svc.tenant_search("bolt", q, filter_mode=mode)
        got = set(t.ids.ravel().tolist()) - {-1}
        assert got and got <= set(ids_b.tolist()), (mode, got)


def test_tenant_quota_enforced_before_mutation(tenant_service):
    svc, ids_a, _, _ = tenant_service
    gen = svc.index.generation
    with pytest.raises(ValueError, match="quota"):
        svc.tenant_insert("acme", np.zeros((37, D), np.float32))
    assert svc.index.generation == gen        # nothing mutated
    assert svc.tenant_stats("acme")["live"] == 64


def test_tenant_delete_ownership(tenant_service):
    svc, ids_a, ids_b, _ = tenant_service
    with pytest.raises(ValueError, match="not owned"):
        svc.tenant_delete("acme", ids_b[:4])
    assert svc.tenant_delete("bolt", ids_b[:8]) == 8
    st = svc.tenant_stats()
    assert st["bolt"]["live"] == 56 and st["acme"]["live"] == 64


def test_tenant_metrics_namespace(tenant_service):
    svc, _, _, q = tenant_service
    svc.tenant_search("acme", q)
    snap = svc.metrics_snapshot()
    assert snap["tenants.acme.live"] == 64
    assert snap["tenants.acme.n_searches"] >= 1
    assert snap["tenants.bolt.label"] == 1


def test_tenant_lanes_share_plans(tenant_service):
    """Scheduler lanes for two tenants differ only in filter VALUE, so
    the second lane's dispatch compiles nothing new."""
    svc, _, _, q = tenant_service
    svc.tenant_search("acme", q)              # compile the filtered plan
    before = len(svc.index.plans)
    svc.tenant_search("bolt", q)
    assert len(svc.index.plans) == before
    assert svc.tenant_spec("acme").resolve() \
        == svc.tenant_spec("bolt").resolve()
