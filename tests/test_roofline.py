"""HLO analyzer + roofline term correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (
    TPU_V5E,
    collective_bytes_from_hlo,
    model_flops,
    roofline_terms,
)
from repro.roofline.hlo_analyzer import HloAnalysis, analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def _cost(compiled):
    """cost_analysis() returns a dict on current jax, [dict] on older jax."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_flops_exact_on_scan_vs_unrolled():
    """Loop-corrected flops from the SCANNED program == unrolled truth."""
    def body(x, w):
        return jnp.tanh(x @ w), None

    x = jax.ShapeDtypeStruct((64, 96), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 96, 96), jnp.float32)
    c_s = _compile(lambda x, ws: jax.lax.scan(body, x, ws)[0], x, ws)
    c_u = _compile(lambda x, ws: jax.lax.scan(body, x, ws, unroll=True)[0],
                   x, ws)
    truth = _cost(c_u)["flops"]
    assert analyze_hlo(c_s.as_text())["flops"] == pytest.approx(truth)
    assert analyze_hlo(c_u.as_text())["flops"] == pytest.approx(truth)


def test_nested_scan_multipliers():
    def inner(x, w):
        return jnp.tanh(x @ w), None

    def outer(x, ws):
        x, _ = jax.lax.scan(inner, x, ws)
        return x, None

    x = jax.ShapeDtypeStruct((96, 96), jnp.float32)
    wss = jax.ShapeDtypeStruct((3, 4, 96, 96), jnp.float32)
    c = _compile(lambda x, wss: jax.lax.scan(outer, x, wss)[0], x, wss)
    got = analyze_hlo(c.as_text())["flops"]
    assert got == pytest.approx(3 * 4 * 2 * 96**3, rel=0.01)


def test_bytes_close_to_xla_on_loop_free():
    def f(a, b):
        return jnp.tanh(a @ b).sum()

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = _compile(f, a, a)
    ana = analyze_hlo(c.as_text())
    xla = _cost(c)["bytes accessed"]
    assert ana["bytes_accessed"] == pytest.approx(xla, rel=0.5)


def test_cost_analysis_undercounts_loops():
    """The raison d'etre: document XLA's body-counted-once behaviour."""
    def body(x, w):
        return jnp.tanh(x @ w), None

    x = jax.ShapeDtypeStruct((64, 96), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 96, 96), jnp.float32)
    c = _compile(lambda x, ws: jax.lax.scan(body, x, ws)[0], x, ws)
    raw = _cost(c)["flops"]
    corrected = analyze_hlo(c.as_text())["flops"]
    assert corrected > 5 * raw  # ~8x


def test_roofline_terms_dominance():
    # compute-bound
    r = roofline_terms(1e15, 1e9, 1e6, 1, TPU_V5E)
    assert r["dominant"] == "compute_s"
    assert r["roofline_fraction"] == pytest.approx(1.0)
    # memory-bound
    r = roofline_terms(1e12, 1e13, 1e6, 1, TPU_V5E)
    assert r["dominant"] == "memory_s"
    assert r["roofline_fraction"] < 1.0
    # collective-bound
    r = roofline_terms(1e12, 1e9, 1e12, 1, TPU_V5E)
    assert r["dominant"] == "collective_s"


def test_model_flops():
    assert model_flops(1000, 10, training=True) == 6000 * 10
    assert model_flops(1000, 10, training=False) == 2000 * 10


def test_collective_parse_shapes():
    hlo = """
ENTRY %main (a: f32[16]) -> f32[16] {
  %a = f32[16]{0} parameter(0)
  %ar = f32[16]{0} all-reduce(%a), replica_groups={}
  %ag = f32[128,64]{1,0} all-gather(%ar), dimensions={0}
  ROOT %r = f32[16]{0} bitcast(%ar)
}
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["all-reduce"]["bytes"] == 16 * 4
    assert got["all-gather"]["bytes"] == 128 * 64 * 4
    assert got["total"]["count"] == 2


def test_analyzer_collectives_weighted_by_loops():
    """Collectives inside a scan body count once per iteration."""
    import os
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (subprocess tests cover this)")
