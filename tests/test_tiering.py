"""Tiered vector storage (core/storage.py): device-resident packed codes,
host-resident f32 rows, pluggable rerank source.

The correctness anchor is BIT-IDENTITY: with the rows evicted to host,
`rerank_source="host"` must reproduce the device tier's ids and
distances bit-for-bit on every search path (the traversal runs on the
same packed codes either way, and the host rerank runs the same
`rerank_frontier` arithmetic on the same gathered rows, followed by the
same stable sort). Everything else — resolve()-time validation, plan
cache keying, churn write-through, checkpoint tier round-trip, the
honest `estimated` flag on code-only serving — hangs off that anchor.

The 4-shard half runs in one subprocess (the XLA fake-device flag must
precede jax init), mirroring tests/test_distributed.py.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SEED = 77
N, D, Q, K, BEAM = 512, 16, 16, 10, 32
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _params():
    from repro.core.construction import ConstructionParams
    return ConstructionParams(degree_bound=16, alpha=1.2, beam_width=16,
                              max_iters=24, rev_cap=16, prune_chunk=256)


def _dataset():
    rng = np.random.default_rng(SEED)
    return (rng.normal(size=(N, D)).astype(np.float32),
            rng.normal(size=(Q, D)).astype(np.float32))


@pytest.fixture(scope="module")
def built():
    """One rabitq index + queries, shared read-only by the spec tests."""
    from repro.core.index import JasperIndex
    data, queries = _dataset()
    idx = JasperIndex(D, capacity=2 * N, construction=_params(),
                      quantization="rabitq", bits=4, seed=SEED)
    idx.build(data)
    idx.delete(np.arange(0, N, 11))
    return idx, queries


# ------------------------------------------------------------- resolution
def test_rerank_source_resolution_rules():
    from repro.core.search_spec import SearchSpec
    # default: exact rerank on device rows
    r = SearchSpec(k=K, quantized=True).resolve()
    assert (r.rerank, r.rerank_source) == (True, "device")
    # code-only: "none" disables the rerank
    r = SearchSpec(k=K, quantized=True, rerank_source="none").resolve()
    assert (r.rerank, r.rerank_source) == (False, "none")
    # back-compat: rerank=False with the default source NORMALIZES to
    # "none" — old and new spellings hit the same plan-cache entry
    a = SearchSpec(k=K, quantized=True, rerank=False).resolve()
    b = SearchSpec(k=K, quantized=True, rerank=True,
                   rerank_source="none").resolve()
    assert a == b and a.rerank_source == "none"
    # host source keeps the exact rerank, just moves its operand
    r = SearchSpec(k=K, quantized=True, rerank_source="host").resolve()
    assert (r.rerank, r.rerank_source) == (True, "host")
    # contradictions fail fast, statically
    with pytest.raises(ValueError, match="contradict"):
        SearchSpec(k=K, quantized=True, rerank=False,
                   rerank_source="host").resolve()
    with pytest.raises(ValueError, match="exact"):
        SearchSpec(k=K, quantized=False, rerank_source="host").resolve()
    with pytest.raises(ValueError, match="exact"):
        SearchSpec(k=K, quantized=False, rerank_source="none").resolve()
    with pytest.raises(ValueError, match="rerank_source"):
        SearchSpec(k=K, quantized=True, rerank_source="bogus").resolve()
    # every (rerank, source) pair resolve() can emit is one of the three
    # legal states
    for spec in (SearchSpec(k=K), SearchSpec(k=K, quantized=True),
                 SearchSpec(k=K, quantized=True, rerank=False),
                 SearchSpec(k=K, quantized=True, rerank_source="none")):
        r = spec.resolve()
        assert (r.rerank, r.rerank_source) in (
            (True, "device"), (True, "host"), (False, "none"))


def test_resolve_checks_index_tier(built):
    from repro.core.search_spec import SearchSpec
    idx, _ = built
    assert idx.rows_tier == "device"
    with pytest.raises(ValueError, match="evicted"):
        SearchSpec(k=K, quantized=True, rerank_source="host").resolve(idx)
    # and the mirror image on a rows-evicted core
    from repro.core.index import JasperIndex
    data, _ = _dataset()
    ev = JasperIndex(D, capacity=N, construction=_params(),
                     quantization="rabitq", bits=4, seed=SEED,
                     rows_tier="host")
    ev.build(data)
    assert ev.rows_tier == "host"
    with pytest.raises(ValueError, match="device-resident"):
        SearchSpec(k=K, quantized=True).resolve(ev)
    # code-only serving never touches the rows: legal on either tier
    SearchSpec(k=K, quantized=True, rerank_source="none").resolve(ev)
    SearchSpec(k=K, quantized=True, rerank_source="none").resolve(idx)


def test_evict_requires_quantizer():
    from repro.core.index import JasperIndex
    data, _ = _dataset()
    idx = JasperIndex(D, capacity=N, construction=_params(), seed=SEED)
    idx.build(data)
    with pytest.raises(ValueError, match="rabitq"):
        idx.evict_rows_to_host()
    with pytest.raises(ValueError, match="rabitq"):
        JasperIndex(D, capacity=N, rows_tier="host")


def test_service_construction_fails_fast(built):
    from repro.core.index import JasperIndex
    from repro.core.search_spec import SearchSpec
    from repro.serving.anns_service import AnnsService
    idx, _ = built
    with pytest.raises(ValueError, match="evicted"):
        AnnsService(idx, spec=SearchSpec(k=K, quantized=True,
                                         rerank_source="host"))
    data, _ = _dataset()
    ev = JasperIndex(D, capacity=N, construction=_params(),
                     quantization="rabitq", bits=4, seed=SEED)
    ev.build(data)
    ev.evict_rows_to_host()
    with pytest.raises(ValueError, match="device-resident"):
        AnnsService(ev, spec=SearchSpec(k=K, quantized=True))


# ------------------------------------------------------------ bit-identity
HOST_LANES = [
    pytest.param({}, id="jnp"),
    pytest.param({"use_kernels": True}, id="kernel"),
    pytest.param({"fusion": "hop"}, id="hop"),
    pytest.param({"fusion": "megakernel"}, id="megakernel"),
    pytest.param({"telemetry": "on"}, id="telemetry"),
    pytest.param({"filter": (1,)}, id="filtered"),
]


@pytest.fixture(scope="module")
def tier_pair():
    """Device-tier results for every lane, then the SAME index evicted —
    {lane_key: device SearchResult} + the evicted index."""
    from repro.core.index import JasperIndex
    from repro.core.search_spec import SearchSpec
    data, queries = _dataset()
    idx = JasperIndex(D, capacity=2 * N, construction=_params(),
                      quantization="rabitq", bits=4, seed=SEED)
    idx.build(data, labels=(np.arange(N) % 2).astype(np.int32))
    idx.delete(np.arange(0, N, 11))
    device = {}
    for p in HOST_LANES:
        kw = p.values[0]
        spec = SearchSpec(k=K, beam_width=BEAM, quantized=True, **kw)
        device[p.id] = idx.searcher(spec).search(queries)
    idx.evict_rows_to_host()
    return idx, queries, device


@pytest.mark.parametrize("kw", HOST_LANES)
def test_host_tier_bit_identical(tier_pair, kw, request):
    from repro.core.search_spec import SearchSpec
    idx, queries, device = tier_pair
    lane = request.node.callspec.id
    spec = SearchSpec(k=K, beam_width=BEAM, quantized=True,
                      rerank_source="host", **kw)
    host = idx.searcher(spec).search(queries)
    dev = device[lane]
    assert np.array_equal(np.asarray(dev.ids), np.asarray(host.ids))
    assert np.array_equal(np.asarray(dev.dists), np.asarray(host.dists))
    assert np.array_equal(np.asarray(dev.n_hops), np.asarray(host.n_hops))
    if kw.get("telemetry") == "on":
        for a, b in zip(dev.telemetry, host.telemetry):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    assert host.estimated is False


def test_memory_stats_track_tiers(tier_pair):
    idx, _, _ = tier_pair
    ms = idx.memory_stats()
    assert ms["rows_tier"] == "host"
    assert ms["device_rows_bytes"] == 0.0
    assert ms["host_rows_bytes"] > 0
    assert ms["device_codes_bytes"] > 0
    assert ms["device_compression_ratio"] > 1.0
    ss = idx.storage_stats()
    assert ss["fetch_n_fetches"] >= 1
    assert ss["fetch_n_bytes"] > 0
    # the effective ratio is (full rows + codes) / codes-only
    rows_full = idx.capacity * (idx.store_dims + 1) * 4
    expect = (rows_full + ms["device_codes_bytes"]) / ms["device_codes_bytes"]
    assert ms["device_compression_ratio"] == pytest.approx(expect)


def test_code_only_lane_reports_estimated(tier_pair):
    from repro.core.search_spec import SearchSpec
    idx, queries, _ = tier_pair
    res = idx.searcher(SearchSpec(k=K, beam_width=BEAM, quantized=True,
                                  rerank_source="none")).search(queries)
    assert res.estimated is True
    host = idx.searcher(SearchSpec(k=K, beam_width=BEAM, quantized=True,
                                   rerank_source="host")).search(queries)
    assert host.estimated is False
    # estimator distances are NOT the exact ones — the flag is load-bearing
    assert not np.array_equal(np.asarray(res.dists), np.asarray(host.dists))


def test_plan_cache_keys_by_rerank_source(tier_pair):
    """Lanes differing only in rerank_source must not share executables,
    and the two spellings of code-only must share one."""
    from repro.core.search_spec import SearchSpec
    idx, queries, _ = tier_pair
    base = dict(k=K, beam_width=BEAM, quantized=True)
    r_host = SearchSpec(**base, rerank_source="host").resolve()
    r_none = SearchSpec(**base, rerank_source="none").resolve()
    r_dev = SearchSpec(**base).resolve()
    assert len({r_host, r_none, r_dev}) == 3
    # live check on the evicted index: host lane = traversal plan +
    # separately-keyed rerank plan; the none lane adds exactly one more
    idx.plans.clear()
    idx.searcher(SearchSpec(**base, rerank_source="host")).search(queries)
    assert len(idx.plans) == 2
    idx.searcher(SearchSpec(**base, rerank_source="none")).search(queries)
    assert len(idx.plans) == 3
    # same spelling again: pure cache hits, no new entries
    idx.searcher(SearchSpec(**base, rerank_source="none")).search(queries)
    idx.searcher(SearchSpec(**base, rerank=False)).search(queries)
    assert len(idx.plans) == 3


def test_scheduler_zero_steady_state_retraces_both_tiers():
    from repro.core.index import JasperIndex
    from repro.core.search_spec import SearchSpec
    from repro.serving.anns_service import AnnsService
    data, queries = _dataset()
    idx = JasperIndex(D, capacity=N, construction=_params(),
                      quantization="rabitq", bits=4, seed=SEED)
    idx.build(data)

    def serve_twice(svc):
        sched = svc.scheduler()
        for q in queries:
            sched.submit(q)
        sched.drain()
        warm = idx.plans.stats.traces
        for q in queries:
            sched.submit(q)
        sched.drain()
        return warm, idx.plans.stats.traces

    warm, steady = serve_twice(AnnsService(
        idx, spec=SearchSpec(k=K, beam_width=BEAM, quantized=True)))
    assert steady == warm, "device tier retraced in steady state"
    idx.evict_rows_to_host()
    warm, steady = serve_twice(AnnsService(
        idx, spec=SearchSpec(k=K, beam_width=BEAM, quantized=True,
                             rerank_source="host")))
    assert steady == warm, "host tier retraced in steady state"


# ------------------------------------------------------------------ churn
def test_churn_keeps_tiers_in_sync():
    """insert/delete/consolidate/grow with rows on the host: device codes
    and host rows must stay consistent — asserted by host-vs-device
    bit-identity AFTER the churn (the device twin is the same index with
    its rows restored)."""
    from repro.core.index import JasperIndex
    from repro.core.search_spec import SearchSpec
    rng = np.random.default_rng(SEED + 1)
    data, queries = _dataset()
    idx = JasperIndex(D, capacity=N, construction=_params(),
                      quantization="rabitq", bits=4, seed=SEED)
    idx.build(data)
    idx.evict_rows_to_host()
    cap0 = idx.capacity
    ids = idx.insert(rng.normal(size=(64, D)).astype(np.float32))
    idx.delete(ids[:16])
    idx.delete(np.arange(0, N, 7))
    idx.consolidate()
    idx.insert(rng.normal(size=(cap0, D)).astype(np.float32))  # forces grow
    assert idx.capacity > cap0
    assert idx.rows_tier == "host"
    host_spec = SearchSpec(k=K, beam_width=BEAM, quantized=True,
                           rerank_source="host")
    host = idx.searcher(host_spec).search(queries)
    idx.restore_rows_to_device()
    dev = idx.searcher(SearchSpec(k=K, beam_width=BEAM,
                                  quantized=True)).search(queries)
    assert np.array_equal(np.asarray(dev.ids), np.asarray(host.ids))
    assert np.array_equal(np.asarray(dev.dists), np.asarray(host.dists))
    # and the host store grew with the capacity
    idx.evict_rows_to_host()
    assert idx.store.host_bytes == idx.capacity * (idx.store_dims + 1) * 4


def test_checkpoint_round_trips_tier(tmp_path):
    from repro.core.index import JasperIndex
    from repro.core.search_spec import SearchSpec
    data, queries = _dataset()
    idx = JasperIndex(D, capacity=N, construction=_params(),
                      quantization="rabitq", bits=4, seed=SEED)
    idx.build(data)
    idx.evict_rows_to_host()
    path = str(tmp_path / "tiered.npz")
    idx.save(path)
    assert idx.rows_tier == "host"           # saving does not flip tiers
    idx2 = JasperIndex.load(path)
    assert idx2.rows_tier == "host"
    ms = idx2.memory_stats()
    assert ms["device_rows_bytes"] == 0.0 and ms["host_rows_bytes"] > 0
    # the tier invariant holds on the restored core: host == device
    # bit-for-bit (cross-checkpoint dists may wobble a ULP because load
    # recomputes vec_sqnorm — both tiers see the same recomputed values)
    host = idx2.searcher(SearchSpec(k=K, beam_width=BEAM, quantized=True,
                                    rerank_source="host")).search(queries)
    idx2.restore_rows_to_device()
    dev = idx2.searcher(SearchSpec(k=K, beam_width=BEAM,
                                   quantized=True)).search(queries)
    assert np.array_equal(np.asarray(dev.ids), np.asarray(host.ids))
    assert np.array_equal(np.asarray(dev.dists), np.asarray(host.dists))


def test_brute_force_works_rows_evicted(built):
    """Ground-truth scans stage the rows in transparently (and put them
    back) — recall measurement works on a host-tier index."""
    from repro.core.index import JasperIndex
    data, queries = _dataset()
    idx = JasperIndex(D, capacity=N, construction=_params(),
                      quantization="rabitq", bits=4, seed=SEED)
    idx.build(data)
    gt_dev, _ = idx.brute_force(queries, K)
    idx.evict_rows_to_host()
    gt_host, _ = idx.brute_force(queries, K)
    assert idx.rows_tier == "host"
    assert np.array_equal(np.asarray(gt_dev), np.asarray(gt_host))


# ------------------------------------------------------------ vector store
def test_vector_store_gather():
    from repro.core.storage import VectorStore, strip_rows
    from repro.core.index_core import init_core
    import jax.numpy as jnp
    from dataclasses import replace
    rng = np.random.default_rng(3)
    rows = rng.normal(size=(32, D)).astype(np.float32)
    core = init_core(32, D, 8)
    core = replace(core, vectors=jnp.asarray(rows),
                   vec_sqnorm=jnp.sum(jnp.asarray(rows) ** 2, axis=-1))
    store = VectorStore()
    stripped = store.evict(core)
    assert stripped.vectors is None and stripped.vec_sqnorm is None
    got, sq = store.gather(np.array([[3, -1], [0, 31]]))
    assert got.shape == (4, D) and sq.shape == (4,)
    np.testing.assert_array_equal(got[0], rows[3])
    np.testing.assert_array_equal(got[1], 0.0)      # -1 -> zero row
    np.testing.assert_array_equal(got[3], rows[31])
    st = store.fetch_stats
    assert st.n_fetches == 1 and st.n_rows == 3     # -1 not counted
    assert st.n_bytes == 3 * (D + 1) * 4
    # attach puts the same bits back
    back = store.attach(stripped)
    np.testing.assert_array_equal(np.asarray(back.vectors), rows)
    assert strip_rows(back).vectors is None


def test_rows_staged_is_reentrant():
    from repro.core.index import JasperIndex
    from repro.core.storage import rows_staged, rows_resident
    data, _ = _dataset()
    idx = JasperIndex(D, capacity=N, construction=_params(),
                      quantization="rabitq", bits=4, seed=SEED)
    idx.build(data)
    idx.evict_rows_to_host()
    assert not rows_resident(idx.core)
    with rows_staged(idx):
        assert rows_resident(idx.core)
        with rows_staged(idx):                      # nested: no-op
            assert rows_resident(idx.core)
        assert rows_resident(idx.core)              # inner exit kept rows
    assert not rows_resident(idx.core)
    assert idx.rows_tier == "host"


# --------------------------------------------------------------- sharded
_SHARDED_TIER_SCRIPT = f"""
import json, numpy as np, jax
from repro.launch.mesh import make_mesh
from repro.core.construction import ConstructionParams
from repro.core.distributed import ShardedJasperIndex
from repro.core.search_spec import SearchSpec

SEED, N, D, Q, K, BEAM = {SEED}, {N}, {D}, {Q}, {K}, {BEAM}
rng = np.random.default_rng(SEED)
data = rng.normal(size=(N, D)).astype(np.float32)
queries = rng.normal(size=(Q, D)).astype(np.float32)
params = ConstructionParams(degree_bound=16, alpha=1.2, beam_width=16,
                            max_iters=24, rev_cap=16, prune_chunk=256)
mesh = make_mesh((4, 2), ("data", "model"))
idx = ShardedJasperIndex(mesh, D, capacity_per_shard=N // 4,
                         construction=params, quantization="rabitq",
                         bits=4, seed=SEED)
idx.build(data, labels=(np.arange(N) % 2).astype(np.int32))
per = N // 4
gids = np.array([s * idx.id_stride + j for s in range(4)
                 for j in range(per)])
idx.delete(gids[::11])

lanes = {{"jnp": {{}}, "kernel": {{"use_kernels": True}},
         "hop": {{"fusion": "hop"}},
         "megakernel": {{"fusion": "megakernel"}},
         "telemetry": {{"telemetry": "on"}}, "filtered": {{"filter": (1,)}}}}
device = {{name: idx.searcher(SearchSpec(k=K, beam_width=BEAM,
                                         quantized=True, **kw)
                              ).search(queries)
           for name, kw in lanes.items()}}
idx.evict_rows_to_host()
report = {{"memory": idx.memory_stats(), "lanes": {{}}}}
for name, kw in lanes.items():
    host = idx.searcher(SearchSpec(k=K, beam_width=BEAM, quantized=True,
                                   rerank_source="host", **kw)
                        ).search(queries)
    dev = device[name]
    ok = (np.array_equal(np.asarray(dev.ids), np.asarray(host.ids))
          and np.array_equal(np.asarray(dev.dists), np.asarray(host.dists))
          and np.array_equal(np.asarray(dev.n_hops),
                             np.asarray(host.n_hops)))
    if name == "telemetry":
        ok = ok and all(np.array_equal(np.asarray(a), np.asarray(b))
                        for a, b in zip(dev.telemetry, host.telemetry))
    report["lanes"][name] = bool(ok)

# churn with rows on the host, then re-verify against the device tier
ids = idx.insert(rng.normal(size=(64, D)).astype(np.float32))
idx.delete(np.asarray(ids).ravel()[:16])
idx.consolidate()
idx.grow(2 * per)
report["tier_after_churn"] = idx.rows_tier
host_spec = SearchSpec(k=K, beam_width=BEAM, quantized=True,
                       rerank_source="host")
host = idx.searcher(host_spec).search(queries)
idx.restore_rows_to_device()
dev = idx.searcher(SearchSpec(k=K, beam_width=BEAM,
                              quantized=True)).search(queries)
report["churn_identical"] = bool(
    np.array_equal(np.asarray(dev.ids), np.asarray(host.ids))
    and np.array_equal(np.asarray(dev.dists), np.asarray(host.dists)))

# checkpoint round-trips the tier layout
import tempfile, os
idx.evict_rows_to_host()
with tempfile.TemporaryDirectory() as td:
    p = os.path.join(td, "ck")
    idx.save(p)
    idx2 = ShardedJasperIndex.load(mesh, p)
    report["loaded_tier"] = idx2.rows_tier
    h = idx2.searcher(host_spec).search(queries)
    idx2.restore_rows_to_device()
    d = idx2.searcher(SearchSpec(k=K, beam_width=BEAM,
                                 quantized=True)).search(queries)
    report["loaded_identical"] = bool(
        np.array_equal(np.asarray(d.ids), np.asarray(h.ids))
        and np.array_equal(np.asarray(d.dists), np.asarray(h.dists)))
print("TIERING_JSON=" + json.dumps(report))
"""


@pytest.fixture(scope="module")
def sharded_tiering():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c",
                          textwrap.dedent(_SHARDED_TIER_SCRIPT)],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, (
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
    import json
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("TIERING_JSON=")][0]
    return json.loads(line[len("TIERING_JSON="):])


@pytest.mark.multidevice
@pytest.mark.slow
@pytest.mark.parametrize("lane", ["jnp", "kernel", "hop", "megakernel",
                                  "telemetry", "filtered"])
def test_four_shard_host_tier_bit_identical(sharded_tiering, lane):
    assert sharded_tiering["lanes"][lane] is True


@pytest.mark.multidevice
@pytest.mark.slow
def test_four_shard_tier_lifecycle(sharded_tiering):
    mem = sharded_tiering["memory"]
    assert mem["rows_tier"] == "host"
    assert mem["device_rows_bytes"] == 0.0
    assert mem["device_compression_ratio"] > 1.0
    assert sharded_tiering["tier_after_churn"] == "host"
    assert sharded_tiering["churn_identical"] is True
    assert sharded_tiering["loaded_tier"] == "host"
    assert sharded_tiering["loaded_identical"] is True
