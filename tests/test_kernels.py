"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rabitq import (
    pack_codes,
    rabitq_encode,
    rabitq_estimate,
    rabitq_preprocess_query,
    rabitq_train,
    unpack_codes,
)
from repro.kernels.distance import ops as dops
from repro.kernels.distance.ref import gather_l2_ref, pairwise_l2_ref
from repro.kernels.rabitq_dot import ops as rops
from repro.kernels.rabitq_dot.ref import rabitq_distance_ref
from repro.kernels.topk import ops as tops
from repro.kernels.topk.ref import topk_ref

RNG = np.random.default_rng(42)


def randn(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ------------------------------------------------------------- pairwise L2
@pytest.mark.parametrize("q,c,d", [
    (8, 128, 128),          # exact tile multiples
    (37, 211, 96),          # ragged everything
    (1, 1, 1),              # degenerate
    (130, 4, 960),          # Gist-dim, tiny C
    (16, 300, 1536),        # OpenAI-dim
])
def test_pairwise_l2_shapes(q, c, d):
    qv, xv = randn(q, d), randn(c, d)
    out = dops.pairwise_l2(qv, xv)
    ref = pairwise_l2_ref(qv, xv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_l2_dtypes(dtype):
    qv = randn(16, 128).astype(dtype)
    xv = randn(64, 128).astype(dtype)
    out = dops.pairwise_l2(qv, xv)
    ref = pairwise_l2_ref(qv, xv)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol * 100)


def test_pairwise_l2_block_sweep():
    qv, xv = randn(64, 256), randn(256, 256)
    ref = pairwise_l2_ref(qv, xv)
    for bq, bc, bd in [(8, 128, 128), (32, 256, 256), (64, 128, 128)]:
        out = dops.pairwise_l2(qv, xv, block_q=bq, block_c=bc, block_d=bd)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-3)


# ------------------------------------------------------------- gather forms
@pytest.mark.parametrize("strategy", ["tiled", "chunked"])
@pytest.mark.parametrize("q,k,d,n", [
    (8, 16, 128, 200),
    (33, 7, 96, 100),
    (4, 64, 960, 64),
])
def test_gather_l2(strategy, q, k, d, n):
    qv, db = randn(q, d), randn(n, d)
    db_sq = jnp.sum(db * db, axis=-1)
    ids = jnp.asarray(RNG.integers(-1, n, (q, k)), jnp.int32)
    fn = dops.gather_l2_tiled if strategy == "tiled" else dops.gather_l2_chunked
    out = fn(qv, db, db_sq, ids)
    ref = gather_l2_ref(qv, db, ids)
    finite = np.isfinite(np.asarray(ref))
    assert (np.isfinite(np.asarray(out)) == finite).all()
    np.testing.assert_allclose(np.asarray(out)[finite], np.asarray(ref)[finite],
                               rtol=1e-4, atol=1e-3)


def test_kernel_scorer_matches_exact_scorer():
    from repro.core.beam_search import make_exact_scorer
    db, qv = randn(128, 64), randn(9, 64)
    n_valid = jnp.int32(100)
    ids = jnp.asarray(RNG.integers(-1, 128, (9, 11)), jnp.int32)
    exact = make_exact_scorer(db, qv, n_valid)(ids)
    kern = dops.make_kernel_scorer(db, qv, n_valid)(ids)
    exact = np.where(np.asarray(ids) >= 0, np.asarray(exact), np.inf)
    # exact scorer returns garbage (not inf) for out-of-range; align masks
    mask = (np.asarray(ids) >= 0) & (np.asarray(ids) < 100)
    np.testing.assert_allclose(np.asarray(kern)[mask], exact[mask],
                               rtol=1e-4, atol=1e-3)
    assert np.all(np.isinf(np.asarray(kern)[~mask]))


# ------------------------------------------------------------------ rabitq
@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("q,n,d", [(8, 64, 128), (19, 100, 96), (4, 32, 960)])
def test_rabitq_kernel_vs_ref(bits, q, n, d):
    db, qv = randn(n, d), randn(q, d)
    params = rabitq_train(jax.random.PRNGKey(0), db, bits=bits)
    codes = rabitq_encode(params, db)
    qq = rabitq_preprocess_query(params, qv)
    packed = codes.packed                    # canonical — already packed
    ref = rabitq_distance_ref(packed, codes.data_add, codes.data_rescale,
                              qq.q_rot, qq.query_add, qq.query_sumq,
                              bits=bits, dims=d)
    out = rops.rabitq_distance(packed, codes.data_add, codes.data_rescale,
                               qq.q_rot, qq.query_add, qq.query_sumq,
                               bits=bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-2)
    # ref itself must agree with the core jnp estimator
    est = rabitq_estimate(codes, qq)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(est),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("bits", [1, 4, 8])
def test_rabitq_gather_kernel(bits):
    n, d, q, k = 90, 128, 12, 9
    db, qv = randn(n, d), randn(q, d)
    params = rabitq_train(jax.random.PRNGKey(1), db, bits=bits)
    codes = rabitq_encode(params, db)
    qq = rabitq_preprocess_query(params, qv)
    packed = codes.packed
    ids = jnp.asarray(RNG.integers(0, n, (q, k)), jnp.int32)
    out = rops.rabitq_gather_distance(
        packed[ids], codes.data_add[ids], codes.data_rescale[ids],
        qq.q_rot, qq.query_add, qq.query_sumq, bits=bits)
    full = rabitq_distance_ref(packed, codes.data_add, codes.data_rescale,
                               qq.q_rot, qq.query_add, qq.query_sumq,
                               bits=bits, dims=d)
    ref = np.take_along_axis(np.asarray(full), np.asarray(ids), axis=1)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("dims", [1, 3, 7, 33, 100, 129])
@pytest.mark.parametrize("bits", [1, 2, 4, 8])
def test_pack_unpack_roundtrip(bits, dims):
    """Round-trips across all SUPPORTED_BITS x odd/non-multiple dims."""
    codes = jnp.asarray(
        RNG.integers(0, 2**bits, (13, dims)), jnp.uint8)
    packed = pack_codes(codes, bits)
    assert packed.shape[1] == int(np.ceil(dims * bits / 8))
    un = unpack_codes(packed, bits, dims)
    assert (np.asarray(un) == np.asarray(codes)).all()


@pytest.mark.parametrize("bits", [1, 4])
def test_pack_unpack_leading_dims(bits):
    """(Q, K, D) batches pack/unpack row-independently."""
    codes = jnp.asarray(RNG.integers(0, 2**bits, (5, 7, 50)), jnp.uint8)
    packed = pack_codes(codes, bits)
    assert packed.shape == (5, 7, int(np.ceil(50 * bits / 8)))
    un = unpack_codes(packed, bits, 50)
    assert (np.asarray(un) == np.asarray(codes)).all()


@pytest.mark.parametrize("bits", [1, 4, 8])
def test_rabitq_search_step_kernel_masks_invalid(bits):
    """Fused search-step kernel: estimator + in-kernel invalid-id masking."""
    from repro.kernels.rabitq_dot.ref import rabitq_search_step_ref

    n, d, q, k = 80, 96, 11, 13
    n_valid = 60
    db, qv = randn(n, d), randn(q, d)
    params = rabitq_train(jax.random.PRNGKey(2), db, bits=bits)
    codes = rabitq_encode(params, db)
    qq = rabitq_preprocess_query(params, qv)
    # ids include -1 (padding) and >= n_valid (stale graph edges)
    ids = jnp.asarray(RNG.integers(-1, n, (q, k)), jnp.int32)
    safe = jnp.maximum(ids, 0)
    cand = codes.packed[safe]
    out = rops.rabitq_search_step(
        cand, codes.data_add[safe], codes.data_rescale[safe], ids,
        jnp.int32(n_valid), qq.q_rot, qq.query_add, qq.query_sumq,
        bits=bits)
    ref = rabitq_search_step_ref(
        cand, codes.data_add[safe], codes.data_rescale[safe], ids,
        n_valid, qq.q_rot, qq.query_add, qq.query_sumq, bits=bits, dims=d)
    mask = np.asarray((ids >= 0) & (ids < n_valid))
    assert (np.isinf(np.asarray(out)) == ~mask).all()
    np.testing.assert_allclose(np.asarray(out)[mask], np.asarray(ref)[mask],
                               rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("bits", [1, 4])
def test_rabitq_search_step_kernel_tombstone_mask(bits):
    """The per-row tombstone bitmap extends the fused epilogue mask: dead
    candidates come back +inf, live ones match the no-tombstone run."""
    from repro.core.mutations import bitmap_gather, pack_bitmap
    from repro.kernels.rabitq_dot.ref import rabitq_search_step_ref

    n, d, q, k = 80, 96, 11, 13
    n_valid = 70
    db, qv = randn(n, d), randn(q, d)
    params = rabitq_train(jax.random.PRNGKey(3), db, bits=bits)
    codes = rabitq_encode(params, db)
    qq = rabitq_preprocess_query(params, qv)
    ids = jnp.asarray(RNG.integers(-1, n, (q, k)), jnp.int32)
    safe = jnp.maximum(ids, 0)
    cand = codes.packed[safe]
    dense = jnp.asarray(RNG.integers(0, 2, n).astype(bool))
    bits_map = pack_bitmap(dense)
    live = (~bitmap_gather(bits_map, safe)).astype(jnp.int32)
    out = rops.rabitq_search_step(
        cand, codes.data_add[safe], codes.data_rescale[safe], ids,
        jnp.int32(n_valid), qq.q_rot, qq.query_add, qq.query_sumq,
        bits=bits, live=live)
    ref = rabitq_search_step_ref(
        cand, codes.data_add[safe], codes.data_rescale[safe], ids,
        n_valid, qq.q_rot, qq.query_add, qq.query_sumq, bits=bits, dims=d,
        live=live)
    mask = np.asarray((ids >= 0) & (ids < n_valid) & (live != 0))
    assert (np.isinf(np.asarray(out)) == ~mask).all()
    np.testing.assert_allclose(np.asarray(out)[mask], np.asarray(ref)[mask],
                               rtol=1e-3, atol=1e-2)


# -------------------------------------------------------------------- topk
@pytest.mark.parametrize("q,c,k", [(8, 128, 10), (5, 300, 32), (64, 64, 64)])
def test_topk_kernel(q, c, k):
    d = randn(q, c)
    i = jnp.arange(q * c, dtype=jnp.int32).reshape(q, c)
    od, oi = tops.topk(d, i, k)
    rd, ri = topk_ref(d, i, k)
    np.testing.assert_allclose(np.asarray(od), np.asarray(rd), rtol=1e-6)
    assert (np.asarray(oi) == np.asarray(ri)).all()


def test_topk_with_ties_and_inf():
    d = jnp.asarray([[1.0, 1.0, np.inf, 0.5], [np.inf, np.inf, np.inf, np.inf]],
                    jnp.float32)
    i = jnp.asarray([[10, 11, 12, 13], [20, 21, 22, 23]], jnp.int32)
    od, oi = tops.topk(d, i, 3)
    assert oi[0, 0] == 13 and od[0, 0] == 0.5
    assert oi[0, 1] == 10  # first occurrence wins the tie
    assert np.isinf(np.asarray(od)[1]).all()


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("b,s,h,hk,dh,causal,window", [
    (2, 128, 4, 4, 32, True, 0),
    (1, 128, 8, 2, 64, True, 0),     # GQA
    (2, 128, 4, 4, 32, False, 0),    # bidirectional (encoder)
    (1, 256, 4, 2, 32, True, 64),    # sliding window
])
def test_flash_attention_vs_ref(b, s, h, hk, dh, causal, window):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    q = randn(b, s, h, dh)
    k = randn(b, s, hk, dh)
    v = randn(b, s, hk, dh)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_kv=64)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_block_sweep():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    q, k, v = randn(1, 256, 4, 32), randn(1, 256, 2, 32), randn(1, 256, 2, 32)
    ref = flash_attention_ref(q, k, v, causal=True)
    for bq, bkv in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_kv=bkv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_flash_traffic_model():
    from repro.kernels.flash_attention.ops import flash_traffic_bytes
    t = flash_traffic_bytes(1, 4, 4, 1024, 1024, 64, block_q=256)
    # q + o once (2 * 1*4*1024*64), kv re-read nq=4 times (2*4*4*1024*64)
    assert t == (2 * 4 * 1024 * 64 + 2 * 4 * 4 * 1024 * 64) * 2


@pytest.mark.parametrize("hk,causal,window", [(4, True, 0), (2, True, 0),
                                              (4, False, 0), (2, True, 64)])
def test_flash_attention_grads_vs_autodiff(hk, causal, window):
    """custom_vjp backward kernels match autodiff of the reference."""
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    b, s, h, dh = 1, 128, 4, 32
    q, k, v = randn(b, s, h, dh), randn(b, s, hk, dh), randn(b, s, hk, dh)
    ct = randn(b, s, h, dh)

    def f_kernel(q_, k_, v_):
        return jnp.sum(flash_attention(q_, k_, v_, causal=causal,
                                       window=window, block_q=64,
                                       block_kv=64) * ct)

    def f_ref(q_, k_, v_):
        return jnp.sum(flash_attention_ref(q_, k_, v_, causal=causal,
                                           window=window) * ct)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------- fused search (ISSUE 6)
@pytest.fixture(scope="module")
def fused_index():
    """Small built index shared by the fused-search kernel tests."""
    from repro.core.construction import ConstructionParams
    from repro.core.index import JasperIndex

    rng = np.random.default_rng(321)
    n, d, q = 384, 16, 16
    data = rng.normal(size=(n, d)).astype(np.float32)
    queries = jnp.asarray(rng.normal(size=(q, d)), jnp.float32)
    params = ConstructionParams(degree_bound=16, alpha=1.2, beam_width=16,
                                max_iters=24, rev_cap=16, prune_chunk=256)
    idx = JasperIndex(d, capacity=n, construction=params,
                      quantization="rabitq", bits=4, seed=321)
    idx.build(data)
    return idx, queries


@pytest.mark.parametrize("schedule", [None, (16, 12, 10)])
def test_fused_search_ref_bitwise_vs_beam_search(fused_index, schedule):
    """The oracle contract: fused_search_ref IS beam_search(merge="topk",
    expand=1) — bit-exact ids, dists, AND hop counts, with or without a
    beam schedule."""
    from repro.core.beam_search import beam_search, make_exact_scorer
    from repro.kernels.search_step.ref import fused_search_ref

    idx, queries = fused_index
    nq = queries.shape[0]
    score = make_exact_scorer(idx.vectors, queries, idx.graph.n_valid,
                              idx.vec_sqnorm)
    res = beam_search(idx.graph, score, nq, beam_width=16, max_iters=40,
                      merge_strategy="topk", beam_schedule=schedule)
    ri, rd, rh = fused_search_ref(
        idx.graph.adjacency, idx.graph.n_valid, idx.graph.medoid, score,
        nq, beam_width=16, max_iters=40, beam_schedule=schedule)
    assert (np.asarray(res.frontier_ids) == np.asarray(ri)).all()
    assert (np.asarray(res.frontier_dists) == np.asarray(rd)).all()
    assert (np.asarray(res.n_hops) == np.asarray(rh)).all()


@pytest.mark.parametrize("quantized", [False, True],
                         ids=["exact", "rabitq"])
@pytest.mark.parametrize("mode", ["hop", "megakernel"])
def test_fused_kernel_vs_ref_oracle(fused_index, quantized, mode):
    """Both Pallas kernels vs the jnp oracle, whole-search: near-total id
    agreement, dists allclose (MXU reduction order differs), hop counts
    exactly equal."""
    from repro.core.beam_search import make_exact_scorer, make_rabitq_scorer
    from repro.core.rabitq import rabitq_preprocess_query
    from repro.kernels.search_step.ops import fused_beam_search
    from repro.kernels.search_step.ref import fused_search_ref

    idx, queries = fused_index
    nq = queries.shape[0]
    if quantized:
        rq = rabitq_preprocess_query(idx.rabitq_params, queries)
        score = make_rabitq_scorer(idx.rabitq_codes, rq)
        res = fused_beam_search(idx.graph, mode=mode, beam_width=16,
                                max_iters=40, codes=idx.rabitq_codes,
                                rq_query=rq)
    else:
        score = make_exact_scorer(idx.vectors, queries, idx.graph.n_valid,
                                  idx.vec_sqnorm)
        res = fused_beam_search(idx.graph, mode=mode, beam_width=16,
                                max_iters=40, queries=queries,
                                vectors=idx.vectors,
                                vec_sqnorm=idx.vec_sqnorm)
    ri, rd, rh = fused_search_ref(
        idx.graph.adjacency, idx.graph.n_valid, idx.graph.medoid, score,
        nq, beam_width=16, max_iters=40)
    agree = float(np.mean(np.asarray(res.frontier_ids) == np.asarray(ri)))
    assert agree >= 0.95, agree
    fin = np.isfinite(np.asarray(rd))
    np.testing.assert_allclose(np.asarray(res.frontier_dists)[fin],
                               np.asarray(rd)[fin], rtol=1e-4, atol=1e-3)
    assert (np.asarray(res.n_hops) == np.asarray(rh)).all()


@pytest.mark.parametrize("mode", ["hop", "megakernel"])
def test_fused_kernel_beam_schedule_vs_ref(fused_index, mode):
    """One narrowing-schedule case straight at the kernel layer."""
    from repro.core.beam_search import make_exact_scorer
    from repro.kernels.search_step.ops import fused_beam_search
    from repro.kernels.search_step.ref import fused_search_ref

    idx, queries = fused_index
    nq = queries.shape[0]
    sched = (16, 12, 10)
    score = make_exact_scorer(idx.vectors, queries, idx.graph.n_valid,
                              idx.vec_sqnorm)
    res = fused_beam_search(idx.graph, mode=mode, beam_width=16,
                            max_iters=40, beam_schedule=sched,
                            queries=queries, vectors=idx.vectors,
                            vec_sqnorm=idx.vec_sqnorm)
    ri, rd, rh = fused_search_ref(
        idx.graph.adjacency, idx.graph.n_valid, idx.graph.medoid, score,
        nq, beam_width=16, max_iters=40, beam_schedule=sched)
    agree = float(np.mean(np.asarray(res.frontier_ids) == np.asarray(ri)))
    assert agree >= 0.95, agree
    assert (np.asarray(res.n_hops) == np.asarray(rh)).all()


@pytest.mark.parametrize("traverse", [False, True],
                         ids=["exclude", "traverse"])
def test_fused_kernel_tombstones_vs_ref(fused_index, traverse):
    """Tombstones through the kernels: exclude mode gathers liveness bytes
    in-kernel, traverse mode filters only the final frontier — both must
    match the oracle and never return a deleted id."""
    from repro.core.beam_search import make_exact_scorer
    from repro.core.mutations import pack_bitmap
    from repro.kernels.search_step.ops import fused_beam_search
    from repro.kernels.search_step.ref import fused_search_ref

    idx, queries = fused_index
    nq = queries.shape[0]
    cap = idx.vectors.shape[0]
    rng = np.random.default_rng(7)
    dead = np.sort(rng.choice(384, 40, replace=False)).astype(np.int32)
    dense = np.zeros((cap,), bool)
    dense[dead] = True
    tomb = pack_bitmap(jnp.asarray(dense))
    score = make_exact_scorer(idx.vectors, queries, idx.graph.n_valid,
                              idx.vec_sqnorm)
    for mode in ("hop", "megakernel"):
        res = fused_beam_search(idx.graph, mode=mode, beam_width=16,
                                max_iters=40, queries=queries,
                                vectors=idx.vectors,
                                vec_sqnorm=idx.vec_sqnorm,
                                tombstone_bits=tomb,
                                traverse_deleted=traverse)
        ids = np.asarray(res.frontier_ids)
        assert not np.isin(ids, dead).any()
        ri, _, rh = fused_search_ref(
            idx.graph.adjacency, idx.graph.n_valid, idx.graph.medoid,
            score, nq, beam_width=16, max_iters=40, tombstone_bits=tomb,
            traverse_deleted=traverse)
        agree = float(np.mean(ids == np.asarray(ri)))
        assert agree >= 0.95, (mode, agree)
        assert (np.asarray(res.n_hops) == np.asarray(rh)).all()
