"""Per-arch smoke tests (deliverable f) + decode-path consistency.

Every assigned architecture instantiates a REDUCED same-family config and
runs one forward/train step on CPU, asserting output shapes + no NaNs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cell_is_runnable
from repro.models import (
    decode_step,
    forward,
    init_decode_state,
    init_params,
    loss_fn,
    param_count,
    param_specs,
    prefill,
    state_specs,
)

RNG = np.random.default_rng(3)
KEY = jax.random.PRNGKey(3)
B, S = 2, 32


def _batch(cfg):
    if cfg.frontend == "frames":
        return {
            "frames": jnp.asarray(RNG.normal(size=(B, S, cfg.d_model)),
                                  jnp.float32),
            "labels": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32),
        }
    return {
        "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke(arch):
    """One forward + train-loss step on the reduced config."""
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, KEY)
    assert param_count(params) > 0
    batch = _batch(cfg)
    logits = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_grads_finite(arch):
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    g = jax.jit(jax.grad(lambda p: loss_fn(p, cfg, batch)[0]))(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(bool(jnp.isfinite(x).all()) for x in leaves)
    gnorm = float(jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                               for x in leaves)))
    assert 0 < gnorm < 1e4


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_match_params(arch):
    """Sharding spec trees must mirror the param tree exactly."""
    cfg = ARCHS[arch].reduced()
    params = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = param_specs(cfg)
    ps = jax.tree_util.tree_structure(params)
    ss = jax.tree_util.tree_structure(
        specs, is_leaf=lambda s: type(s) is tuple)
    assert ps == ss
    # spec rank == param rank
    for (path, leaf), spec in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda s: type(s) is tuple)):
        assert len(spec) == len(leaf.shape), (path, spec, leaf.shape)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "starcoder2-7b",
                                  "granite-moe-1b-a400m", "xlstm-125m",
                                  "zamba2-2.7b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the parallel forward exactly."""
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32",
                              capacity_factor=8.0)
    params = init_params(cfg, KEY)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, 16)), jnp.int32)
    ref = forward(params, cfg, {"tokens": tokens})
    state = init_decode_state(cfg, B, max_len=16)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))
    outs = []
    for t in range(16):
        lg, state = step(params, state, tokens[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "olmoe-1b-7b",
                                  "zamba2-2.7b"])
def test_prefill_then_decode(arch):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32",
                              capacity_factor=8.0)
    params = init_params(cfg, KEY)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, 16)), jnp.int32)
    ref = forward(params, cfg, {"tokens": tokens})
    lg_pre, st = prefill(params, cfg, {"tokens": tokens[:, :8]}, max_len=16)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(ref[:, :8]),
                               rtol=2e-3, atol=2e-3)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))
    for t in range(8, 16):
        lg, st = step(params, st, tokens[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(ref[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_encoder_has_no_decode():
    cfg = ARCHS["hubert-xlarge"].reduced()
    with pytest.raises(ValueError):
        init_decode_state(cfg, 2, 16)


def test_encoder_is_bidirectional():
    """Changing a LATE frame must affect EARLY frame logits (no causality)."""
    cfg = dataclasses.replace(ARCHS["hubert-xlarge"].reduced(),
                              dtype="float32")
    params = init_params(cfg, KEY)
    frames = jnp.asarray(RNG.normal(size=(1, 16, cfg.d_model)), jnp.float32)
    out1 = forward(params, cfg, {"frames": frames})
    frames2 = frames.at[0, 12].add(1.0)
    out2 = forward(params, cfg, {"frames": frames2})
    assert float(jnp.max(jnp.abs(out1[0, 0] - out2[0, 0]))) > 1e-6


def test_causal_archs_are_causal():
    cfg = dataclasses.replace(ARCHS["stablelm-1.6b"].reduced(),
                              dtype="float32")
    params = init_params(cfg, KEY)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    out1 = forward(params, cfg, {"tokens": toks})
    toks2 = toks.at[0, 12].set((int(toks[0, 12]) + 1) % cfg.vocab_size)
    out2 = forward(params, cfg, {"tokens": toks2})
    # positions before 12 unchanged
    np.testing.assert_allclose(np.asarray(out1[0, :12]),
                               np.asarray(out2[0, :12]), atol=1e-5)
    assert float(jnp.max(jnp.abs(out1[0, 12:] - out2[0, 12:]))) > 1e-6


def test_moe_drops_tokens_at_low_capacity():
    import functools
    from repro.models.moe import moe_init, moe_with_aux
    cfg = dataclasses.replace(ARCHS["olmoe-1b-7b"].reduced(),
                              dtype="float32", capacity_factor=0.25)
    params = moe_init(KEY, cfg)
    x = jnp.asarray(RNG.normal(size=(2, 32, cfg.d_model)), jnp.float32)
    out_low, _ = moe_with_aux(params, x, cfg)
    cfg_hi = dataclasses.replace(cfg, capacity_factor=8.0)
    out_hi, _ = moe_with_aux(params, x, cfg_hi)
    # capacity pressure must change outputs (tokens dropped)
    assert float(jnp.max(jnp.abs(out_low - out_hi))) > 1e-6


def test_cell_runnability_table():
    """31 runnable cells + 9 documented skips (DESIGN.md table)."""
    runnable = skipped = 0
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            ok, why = cell_is_runnable(cfg, shape)
            if ok:
                runnable += 1
            else:
                skipped += 1
                assert why
    assert runnable == 31 and skipped == 9


def test_window_attention_limits_context():
    """Sliding-window arch: token far outside the window has no effect."""
    cfg = dataclasses.replace(ARCHS["zamba2-2.7b"].reduced(),
                              dtype="float32", sliding_window=8,
                              num_layers=2, attn_every=1)
    params = init_params(cfg, KEY)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 32)), jnp.int32)
    out1 = forward(params, cfg, {"tokens": toks})
    # change token 0; position 31 attends only to (23, 31] + mamba state.
    # attention contribution from pos 0 must be zero => only the (bounded)
    # mamba state carries info; verify finite + shape here and the strict
    # window mask via blockwise_attention directly:
    from repro.models.attention import blockwise_attention
    q = jnp.asarray(RNG.normal(size=(1, 32, 4, 8)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 32, 4, 8)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 32, 4, 8)), jnp.float32)
    o1 = blockwise_attention(q, k, v, causal=True, window=8, q_chunk=16,
                             kv_chunk=16)
    k2 = k.at[0, 0].add(10.0)
    v2 = v.at[0, 0].add(10.0)
    o2 = blockwise_attention(q, k2, v2, causal=True, window=8, q_chunk=16,
                             kv_chunk=16)
    np.testing.assert_allclose(np.asarray(o1[0, 16:]), np.asarray(o2[0, 16:]),
                               atol=1e-5)


def test_flash_kernel_model_path_matches_blockwise():
    """cfg.use_flash_kernel swaps in the Pallas kernel; logits identical."""
    cfg = dataclasses.replace(ARCHS["stablelm-1.6b"].reduced(),
                              dtype="float32")
    params = init_params(cfg, KEY)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 64)), jnp.int32)
    ref = forward(params, cfg, {"tokens": toks})
    cfg2 = dataclasses.replace(cfg, use_flash_kernel=True)
    out = forward(params, cfg2, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_generate_greedy_deterministic():
    from repro.serving.serve_loop import generate
    cfg = dataclasses.replace(ARCHS["stablelm-1.6b"].reduced(),
                              dtype="float32")
    params = init_params(cfg, KEY)
    prompts = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    out1 = generate(params, cfg, prompts, max_new_tokens=6)
    out2 = generate(params, cfg, prompts, max_new_tokens=6)
    assert out1.shape == (2, 14)
    assert (np.asarray(out1) == np.asarray(out2)).all()
    assert (np.asarray(out1[:, :8]) == np.asarray(prompts)).all()


def test_prefill_last_only_matches_full():
    cfg = dataclasses.replace(ARCHS["minicpm-2b"].reduced(), dtype="float32")
    params = init_params(cfg, KEY)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    full = forward(params, cfg, {"tokens": toks})
    lg, _ = prefill(params, cfg, {"tokens": toks}, max_len=16, last_only=True)
    assert lg.shape[1] == 1
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_multi_expansion_beam_search_recall():
    """E>1 multi-expansion preserves recall with 1/E the iterations."""
    from repro.core.beam_search import beam_search, make_exact_scorer
    from repro.core.construction import ConstructionParams
    from repro.core.index import JasperIndex
    rng = np.random.default_rng(5)
    data = rng.normal(size=(1500, 32)).astype(np.float32)
    queries = jnp.asarray(rng.normal(size=(50, 32)), jnp.float32)
    idx = JasperIndex(32, capacity=1500, construction=ConstructionParams(
        degree_bound=16, beam_width=16, max_iters=24, rev_cap=16,
        prune_chunk=256))
    idx.build(data)
    gt, _ = idx.brute_force(queries, 10)
    score = make_exact_scorer(idx.vectors, queries, idx.graph.n_valid,
                              idx.vec_sqnorm)

    def recall(res):
        ids = np.asarray(res.frontier_ids[:, :10])
        g = np.asarray(gt)
        return np.mean([len(set(ids[i]) & set(g[i])) / 10 for i in range(50)])

    r1 = recall(beam_search(idx.graph, score, 50, beam_width=32,
                            max_iters=64, expand_per_iter=1))
    r4 = recall(beam_search(idx.graph, score, 50, beam_width=32,
                            max_iters=16, expand_per_iter=4))
    assert r4 > r1 - 0.05, (r1, r4)
